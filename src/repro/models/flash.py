"""Blocked (FlashAttention-style) attention in pure JAX with a
recompute-backward custom VJP.

Used by ``layers.attention_scores`` for long sequences so neither the
forward nor the backward ever materializes the (L, L) score matrix:

* forward: online-softmax over kv blocks (running max / normalizer);
* backward: recomputes the per-block probabilities from the saved
  (q, k, v, o, m, l) - the standard FlashAttention-2 recipe - so memory
  stays O(L * block) under ``jax.grad`` and ``jax.checkpoint``.

This is also the numerical oracle for the Pallas ``flash_attention``
kernel (kernels/ref.py re-exports it).

Shapes: q (B, Lq, H, D); k/v (B, Lk, H, D) - GQA expansion happens in the
caller.  Causal masking uses absolute positions (q_offset supports
q-chunked callers); ``window`` adds a sliding-window lower bound.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

DEFAULT_BLOCK = 512
NEG_INF = -1e30


def _pad_to(x, block, axis):
    l = x.shape[axis]
    pad = (-l) % block
    if pad == 0:
        return x, l
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), l


def _mask(qpos, kpos, causal, window, kv_len=None):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    if kv_len is not None:
        m &= kpos[None, :] < kv_len   # block-padding on the kv axis
    return m


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    q_offset: int = 0, block: int = DEFAULT_BLOCK):
    out, _ = _flash_fwd(q, k, v, causal, window, q_offset, block)
    return out


def _flash_core(q, k, v, causal, window, q_offset, block, kv_len=None):
    """Returns (o, m, l) for the padded inputs."""
    b, lq, h, d = q.shape
    lk = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    nq, nk = lq // block, lk // block

    qb = q.reshape(b, nq, block, h, d)
    kb = k.reshape(b, nk, block, h, d)
    vb = v.reshape(b, nk, block, h, d)

    def q_step(_, qi):
        q_i, iq = qi
        qpos = q_offset + iq * block + jnp.arange(block)

        def kv_step(carry, kvj):
            m_run, l_run, acc = carry
            k_j, v_j, jk = kvj
            kpos = jk * block + jnp.arange(block)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpos, kpos, causal, window, kv_len)
            s = jnp.where(msk[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, block), jnp.float32)
        a0 = jnp.zeros((b, h, block, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
        o = acc / jnp.maximum(l, 1e-20)[..., None]
        return None, (o, m, l)

    _, (o, m, l) = jax.lax.scan(
        q_step, None, (qb.swapaxes(0, 1), jnp.arange(nq)))
    # o: (nq, b, h, block, d) -> (b, lq, h, d)
    o = o.transpose(1, 0, 3, 2, 4).reshape(b, lq, h, d)
    m = m.transpose(1, 0, 3, 2).reshape(b, lq, h)
    l = l.transpose(1, 0, 3, 2).reshape(b, lq, h)
    return o, m, l


def _flash_fwd(q, k, v, causal, window, q_offset, block):
    qp, lq = _pad_to(q, block, 1)
    kp, lk = _pad_to(k, block, 1)
    vp, _ = _pad_to(v, block, 1)
    if kp.shape[1] != k.shape[1]:
        # padded kv rows must never win the max: rely on causal/pos mask
        pass
    o, m, l = _flash_core(qp, kp, vp, causal, window, q_offset, block, kv_len=lk)
    out = o[:, :lq].astype(q.dtype)
    return out, (q, k, v, out, m[:, :lq], l[:, :lq])


def _flash_bwd(causal, window, q_offset, block, res, do):
    q, k, v, o, m, l = res
    b, lq, h, d = q.shape
    lk = k.shape[1]
    scale = 1.0 / math.sqrt(d)

    qp, _ = _pad_to(q, block, 1)
    kp, _ = _pad_to(k, block, 1)
    vp, _ = _pad_to(v, block, 1)
    op, _ = _pad_to(o, block, 1)
    dop, _ = _pad_to(do, block, 1)
    mp, _ = _pad_to(m, block, 1)
    lp, _ = _pad_to(l, block, 1)
    nq, nk = qp.shape[1] // block, kp.shape[1] // block

    # D = rowsum(dO * O)
    Dmat = jnp.sum(dop.astype(jnp.float32) * op.astype(jnp.float32),
                   axis=-1)                       # (b, lqp, h)

    qb = qp.reshape(b, nq, block, h, d)
    kb = kp.reshape(b, nk, block, h, d)
    vb = vp.reshape(b, nk, block, h, d)
    dob = dop.reshape(b, nq, block, h, d)
    mb = mp.reshape(b, nq, block, h)
    lb = lp.reshape(b, nq, block, h)
    Db = Dmat.reshape(b, nq, block, h)

    def kv_step(_, kvj):
        k_j, v_j, jk = kvj
        kpos = jk * block + jnp.arange(block)

        def q_step(carry, qi):
            dk_run, dv_run = carry
            q_i, do_i, m_i, l_i, D_i, iq = qi
            qpos = q_offset + iq * block + jnp.arange(block)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpos, kpos, causal, window, lk)
            s = jnp.where(msk[None, None], s, NEG_INF)
            p = jnp.exp(s - m_i.transpose(0, 2, 1)[..., None]) / \
                jnp.maximum(l_i.transpose(0, 2, 1)[..., None], 1e-20)
            dp = jnp.einsum("bqhd,bkhd->bhqk",
                            do_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p * (dp - D_i.transpose(0, 2, 1)[..., None]) * scale
            dk = jnp.einsum("bhqk,bqhd->bkhd", ds,
                            q_i.astype(jnp.float32))
            dv = jnp.einsum("bhqk,bqhd->bkhd", p,
                            do_i.astype(jnp.float32))
            return (dk_run + dk, dv_run + dv), None

        z = jnp.zeros((b, block, h, d), jnp.float32)
        (dk, dv), _ = jax.lax.scan(
            q_step, (z, z),
            (qb.swapaxes(0, 1), dob.swapaxes(0, 1), mb.swapaxes(0, 1),
             lb.swapaxes(0, 1), Db.swapaxes(0, 1), jnp.arange(nq)))
        return None, (dk, dv)

    _, (dk, dv) = jax.lax.scan(
        kv_step, None,
        (kb.swapaxes(0, 1), vb.swapaxes(0, 1), jnp.arange(nk)))
    dk = dk.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, d)[:, :lk]
    dv = dv.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, d)[:, :lk]

    def dq_q_step(_, qi):
        q_i, do_i, m_i, l_i, D_i, iq = qi
        qpos = q_offset + iq * block + jnp.arange(block)

        def dq_kv_step(dq_run, kvj):
            k_j, v_j, jk = kvj
            kpos = jk * block + jnp.arange(block)
            s = jnp.einsum("bqhd,bkhd->bhqk", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            msk = _mask(qpos, kpos, causal, window, lk)
            s = jnp.where(msk[None, None], s, NEG_INF)
            p = jnp.exp(s - m_i.transpose(0, 2, 1)[..., None]) / \
                jnp.maximum(l_i.transpose(0, 2, 1)[..., None], 1e-20)
            dp = jnp.einsum("bqhd,bkhd->bhqk",
                            do_i.astype(jnp.float32),
                            v_j.astype(jnp.float32))
            ds = p * (dp - D_i.transpose(0, 2, 1)[..., None]) * scale
            dq = jnp.einsum("bhqk,bkhd->bqhd", ds,
                            k_j.astype(jnp.float32))
            return dq_run + dq, None

        z = jnp.zeros((b, block, h, d), jnp.float32)
        dq, _ = jax.lax.scan(dq_kv_step, z,
                             (kb.swapaxes(0, 1), vb.swapaxes(0, 1),
                              jnp.arange(nk)))
        return None, dq

    _, dq = jax.lax.scan(
        dq_q_step, None,
        (qb.swapaxes(0, 1), dob.swapaxes(0, 1), mb.swapaxes(0, 1),
         lb.swapaxes(0, 1), Db.swapaxes(0, 1), jnp.arange(nq)))
    dq = dq.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, d)[:, :lq]
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype))


flash_attention.defvjp(_flash_fwd, _flash_bwd)
