"""Parallel context: how a model invocation is sharded.

All model code takes a ``ParallelContext``; with ``tp_axis=None`` the code
runs unsharded (CPU smoke tests).  All collectives route through the
CXL-CCL ``Communicator`` so the backend (``ring`` vs ``cxl``) is a launch
flag, never a model change.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax.numpy as jnp
from jax import lax

from repro.core.api import Communicator


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    tp_axis: Optional[str] = None                 # model/tensor parallel
    dp_axis: Optional[Union[str, tuple]] = None   # data/FSDP axis (maybe
                                                  # hierarchical)
    tp: int = 1                                   # static tp size
    comm: Communicator = Communicator()

    def tp_all_reduce(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.tp_axis is None or self.tp == 1:
            return x
        return self.comm.all_reduce(x, self.tp_axis)

    def tp_all_gather(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.tp_axis is None or self.tp == 1:
            return x
        return self.comm.all_gather(x, self.tp_axis)

    def tp_all_to_all(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.tp_axis is None or self.tp == 1:
            return x
        return self.comm.all_to_all(x, self.tp_axis)

    def tp_psum_max(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.tp_axis is None or self.tp == 1:
            return x
        return lax.pmax(x, self.tp_axis)

    def tp_max(self, x: jnp.ndarray) -> jnp.ndarray:
        """Cross-shard max that is safe under differentiation (lax.pmax
        has no AD rule): stack via all-gather, reduce locally.  Payloads
        are tiny (per-token scalars)."""
        if self.tp_axis is None or self.tp == 1:
            return x
        stacked = self.comm.all_gather(x[None], self.tp_axis)
        return jnp.max(stacked, axis=0)

    def tp_index(self):
        if self.tp_axis is None or self.tp == 1:
            return jnp.int32(0)
        return lax.axis_index(self.tp_axis)

    def dp_all_reduce_mean(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.dp_axis is None:
            return x
        ax = self.dp_axis
        total = self.comm.all_reduce(x, ax)
        size = 1
        # static size lookup is the caller's job; use pmean-equivalent
        if isinstance(ax, str):
            size = lax.axis_size(ax)
        else:
            for a in ax:
                size = size * lax.axis_size(a)
        return total / size


UNSHARDED = ParallelContext()
