"""Pallas TPU kernel: blocked causal attention (FlashAttention forward).

Canonical TPU formulation: 3-D grid (batch*heads, q_blocks, kv_blocks)
with the kv dimension innermost (sequential on TPU), online-softmax state
(m, l, acc) carried across kv steps in VMEM scratch, initialized at
jk == 0 and written out at the last kv block.  Block shapes are
MXU-aligned (q_block x head_dim and head_dim x kv_block matmuls).

Layout: q/k/v (BH, L, D) - the wrapper folds batch and (already
GQA-expanded) heads.  Supports causal masking and a sliding window.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
BLOCK_Q = 128
BLOCK_K = 128


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, window, nk: int, block_q: int,
            block_k: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                  # (bq, d)
    k = k_ref[0]                                  # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    kpos = jk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + pv
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(jk == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window=None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q/k/v: (BH, L, D) with L divisible by the block sizes."""
    bh, lq, d = q.shape
    lk = k.shape[1]
    if lq % block_q or lk % block_k:
        raise ValueError("sequence must divide the block size")
    nq, nk = lq // block_q, lk // block_k
    scale = 1.0 / math.sqrt(d)
    kern = functools.partial(_kernel, scale=scale, causal=causal,
                             window=window, nk=nk, block_q=block_q,
                             block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[
            # (bq, 1) running max / normalizer and (bq, d) accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
