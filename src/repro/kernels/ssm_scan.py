"""Pallas TPU kernel: selective-state-space scan (Mamba-1 core).

Computes, per batch and channel block:

    h_t = exp(dt_t * A) * h_{t-1} + (dt_t * x_t) * B_t
    y_t = <h_t, C_t> + D * x_t

TPU adaptation (DESIGN.md): the CUDA kernel's warp-level scan does not
map to the MXU/VPU, so the kernel keeps the recurrent state
(block_d, N) resident in VMEM scratch and walks the sequence dimension
as the innermost (sequential) grid axis, processing ``block_l`` steps
per invocation with a ``fori_loop`` of rank-2 VPU ops.  Channels are the
vectorized dim (block_d lanes), so throughput is bound by dt*A exps and
the (block_d, N) FMAs - exactly the arithmetic the paper's GPU kernel
does per thread, re-vectorized for the VPU.

Layouts: x/dt (B, L, D), A (D, N), Bs/Cs (B, L, N), D_res (D,)
-> y (B, L, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_D = 256
BLOCK_L = 128


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, dres_ref, y_ref, h_scr,
            *, block_l: int):
    jl = pl.program_id(2)

    @pl.when(jl == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[...].astype(jnp.float32)            # (bd, N)
    dres = dres_ref[...].astype(jnp.float32)      # (bd,)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)      # (bd,)
        dtt = dt_ref[0, t].astype(jnp.float32)    # (bd,)
        bt = b_ref[0, t].astype(jnp.float32)      # (N,)
        ct = c_ref[0, t].astype(jnp.float32)      # (N,)
        decay = jnp.exp(dtt[:, None] * a)         # (bd, N)
        h = decay * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=-1) + dres * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, block_l, step, h_scr[...])


@functools.partial(jax.jit, static_argnames=(
    "block_d", "block_l", "interpret"))
def ssm_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             bs: jnp.ndarray, cs: jnp.ndarray, d_res: jnp.ndarray,
             block_d: int = BLOCK_D, block_l: int = BLOCK_L,
             interpret: bool = True) -> jnp.ndarray:
    """See module docstring for shapes."""
    b, l, d = x.shape
    n = a.shape[1]
    bd = min(block_d, d)
    bl = min(block_l, l)
    if d % bd or l % bl:
        raise ValueError("d / l must divide the block sizes")
    grid = (b, d // bd, l // bl)   # seq innermost: sequential carry
    return pl.pallas_call(
        functools.partial(_kernel, block_l=bl),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bl, bd), lambda ib, idd, il: (ib, il, idd)),
            pl.BlockSpec((1, bl, bd), lambda ib, idd, il: (ib, il, idd)),
            pl.BlockSpec((bd, n), lambda ib, idd, il: (idd, 0)),
            pl.BlockSpec((1, bl, n), lambda ib, idd, il: (ib, il, 0)),
            pl.BlockSpec((1, bl, n), lambda ib, idd, il: (ib, il, 0)),
            pl.BlockSpec((bd,), lambda ib, idd, il: (idd,)),
        ],
        out_specs=pl.BlockSpec((1, bl, bd),
                               lambda ib, idd, il: (ib, il, idd)),
        out_shape=jax.ShapeDtypeStruct((b, l, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, bs, cs, d_res)
