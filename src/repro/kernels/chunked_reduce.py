"""Pallas TPU kernel: chunked multi-source reduction.

This is the consumer-side hot spot of the paper's AllReduce / Reduce /
ReduceScatter: after the retrieve phase a rank holds N peers' chunks and
reduces them locally ("each rank must perform its own full reduction",
Sec. 5.2).  On TPU the chunks arrive via the ppermute schedule; this
kernel fuses the N-way add over VMEM-resident tiles with f32
accumulation, one grid step per output tile - the tile size is the
paper's slicing-factor chunk mapped to VMEM.

x: (n_src, length) -> out: (length,) = sum over sources.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 2048


def _kernel(x_ref, o_ref):
    # x_ref: (n_src, tile) VMEM block; accumulate in f32 on the VPU.
    acc = jnp.sum(x_ref[...].astype(jnp.float32), axis=0)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def chunked_reduce(x: jnp.ndarray, tile: int = DEFAULT_TILE,
                   interpret: bool = True) -> jnp.ndarray:
    """Sum ``x`` (n_src, length) over sources, tiled along length."""
    n_src, length = x.shape
    pad = (-length) % tile
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    padded = length + pad
    grid = (padded // tile,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n_src, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), x.dtype),
        interpret=interpret,
    )(x)
    return out[:length]
