"""Pallas TPU kernels fusing collective edges into adjacent compute.

The collective layer and the kernel layer used to meet only through
HBM: a ReduceScatter lands its bytes, then rmsnorm (or the optimizer)
reads the very same bytes right back; an FSDP AllGather materializes a
full weight only for the next matmul to stream it in again.  The three
kernels here close that gap (ROADMAP item 4):

* ``reduce_scatter_rmsnorm`` - the consumer-side final accumulation of
  a ReduceScatter (a rank holds the n_src peers' partials of its own
  segment, ``chunked_reduce`` style) with the rmsnorm epilogue applied
  in-register before writeback: one HBM write instead of a write + a
  full read + another write.
* ``reduce_scatter_adamw`` - the same accumulation with the AdamW
  update as the epilogue: the summed gradient segment never exists in
  HBM; the kernel emits updated param + moments directly (the FSDP
  grad-sync -> optimizer hot path).
* ``all_gather_matmul`` - a matmul whose contraction streams the
  gathered operand shard-by-shard: the grid's innermost axis walks the
  rank-major shard stack, so Pallas's pipelined block fetch brings
  shard k+1 into VMEM while shard k is on the MXU (the
  ``flash_attention`` kv-innermost pattern).  ``fused_dense`` wraps it
  with a custom VJP so it can sit on the differentiated FSDP path
  (``models.layers.dense``); the backward pass is plain-jnp reference
  math.

Pure-jnp oracles live in ``kernels.ref``; ``kernels.ops`` carries the
interpret-defaulting public wrappers.  Accumulation is f32 throughout,
matching the unfused reference composition op-for-op so fp32 inputs
reproduce it bitwise where the schedule permits (the elementwise
epilogues; the matmul differs only in f32 summation order).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_TILE = 128          # token rows per grid step (rs+rmsnorm, matmul)
SEG_TILE = 2048         # flat elements per grid step (rs+adamw)


# --------------------------------------------------------------------- #
# reduce_scatter + rmsnorm epilogue
# --------------------------------------------------------------------- #

def _rs_rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    # x_ref: (n_src, rows, D) VMEM block - the peers' partials of this
    # rank's segment.  Accumulate f32, normalize in-register, write once.
    acc = jnp.sum(x_ref[...].astype(jnp.float32), axis=0)   # (rows, D)
    var = jnp.mean(jnp.square(acc), axis=-1, keepdims=True)
    y = acc * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "rows", "interpret"))
def reduce_scatter_rmsnorm(shards: jnp.ndarray, scale: jnp.ndarray,
                           eps: float = 1e-5, rows: int = ROW_TILE,
                           interpret: bool = True) -> jnp.ndarray:
    """``shards``: (n_src, T, D) peer partials -> (T, D) normalized sum."""
    n_src, t, d = shards.shape
    r = min(rows, t)
    pad = (-t) % r
    if pad:
        shards = jnp.pad(shards, ((0, 0), (0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rs_rmsnorm_kernel, eps=eps),
        grid=((t + pad) // r,),
        in_specs=[pl.BlockSpec((n_src, r, d), lambda i: (0, i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t + pad, d), shards.dtype),
        interpret=interpret,
    )(shards, scale)
    return out[:t]


# --------------------------------------------------------------------- #
# reduce_scatter + AdamW epilogue
# --------------------------------------------------------------------- #

def _rs_adamw_kernel(g_ref, p_ref, m_ref, v_ref, h_ref,
                     po_ref, mo_ref, vo_ref, *, b1: float, b2: float,
                     eps: float, weight_decay: float):
    # g_ref: (n_src, tile) grad partials; h_ref: (3,) = [lr, bc1, bc2]
    # (traced scalars - lr comes from a schedule).  The summed gradient
    # lives only in VMEM; updated param + f32 moments write out.
    g = jnp.sum(g_ref[...].astype(jnp.float32), axis=0)
    lr, bc1, bc2 = h_ref[0], h_ref[1], h_ref[2]
    m = b1 * m_ref[...] + (1.0 - b1) * g
    v = b2 * v_ref[...] + (1.0 - b2) * jnp.square(g)
    mhat = m / bc1
    vhat = v / bc2
    delta = mhat / (jnp.sqrt(vhat) + eps)
    p32 = p_ref[...].astype(jnp.float32)
    if weight_decay:
        delta = delta + weight_decay * p32
    po_ref[...] = (p32 - lr * delta).astype(po_ref.dtype)
    mo_ref[...] = m
    vo_ref[...] = v


@functools.partial(jax.jit, static_argnames=(
    "b1", "b2", "eps", "weight_decay", "tile", "interpret"))
def reduce_scatter_adamw(shards: jnp.ndarray, p: jnp.ndarray,
                         m: jnp.ndarray, v: jnp.ndarray, lr, bc1, bc2,
                         b1: float = 0.9, b2: float = 0.95,
                         eps: float = 1e-8, weight_decay: float = 0.0,
                         tile: int = SEG_TILE,
                         interpret: bool = True) -> tuple:
    """``shards``: (n_src, L) grad partials; ``p``/``m``/``v``: (L,)
    param and f32 moments; ``lr``/``bc1``/``bc2`` traced scalars (the
    schedule LR and bias corrections ``1 - b^step``).  Returns
    (new_p, new_m, new_v) - the AdamW math of ``optim.adamw_update``
    applied to the in-register sum of the partials."""
    n_src, length = shards.shape
    hyper = jnp.stack([jnp.float32(lr), jnp.float32(bc1),
                       jnp.float32(bc2)])
    t = min(tile, length)
    pad = (-length) % t
    if pad:
        shards = jnp.pad(shards, ((0, 0), (0, pad)))
        p = jnp.pad(p, (0, pad))
        m = jnp.pad(m, (0, pad))
        v = jnp.pad(v, (0, pad))
    padded = length + pad
    new_p, new_m, new_v = pl.pallas_call(
        functools.partial(_rs_adamw_kernel, b1=b1, b2=b2, eps=eps,
                          weight_decay=weight_decay),
        grid=(padded // t,),
        in_specs=[pl.BlockSpec((n_src, t), lambda i: (0, i)),
                  pl.BlockSpec((t,), lambda i: (i,)),
                  pl.BlockSpec((t,), lambda i: (i,)),
                  pl.BlockSpec((t,), lambda i: (i,)),
                  pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[pl.BlockSpec((t,), lambda i: (i,)),
                   pl.BlockSpec((t,), lambda i: (i,)),
                   pl.BlockSpec((t,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((padded,), p.dtype),
                   jax.ShapeDtypeStruct((padded,), jnp.float32),
                   jax.ShapeDtypeStruct((padded,), jnp.float32)],
        interpret=interpret,
    )(shards, p, m, v, hyper)
    return new_p[:length], new_m[:length], new_v[:length]


# --------------------------------------------------------------------- #
# all_gather fused into the consuming matmul's prologue
# --------------------------------------------------------------------- #

def _ag_matmul_kernel(x_ref, w_ref, o_ref, acc_scr, *, nk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # shard k multiplies while the pipeline fetches shard k+1 (the
    # innermost grid axis is sequential on TPU; Pallas double-buffers
    # the HBM->VMEM block copies).
    acc_scr[...] += jax.lax.dot_general(
        x_ref[...], w_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _finalize():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("rows", "interpret"))
def all_gather_matmul(x: jnp.ndarray, w_shards: jnp.ndarray,
                      rows: int = ROW_TILE,
                      interpret: bool = True) -> jnp.ndarray:
    """``x``: (T, n*Ks) activations; ``w_shards``: (n, Ks, N) rank-major
    gathered weight shards.  Returns ``x @ concat(w_shards)`` without
    ever materializing the concatenated weight: the contraction streams
    the shard stack through VMEM, one shard per (sequential) grid step.
    """
    n, ks, nout = w_shards.shape
    t, kdim = x.shape
    if kdim != n * ks:
        raise ValueError(
            f"contraction mismatch: x has {kdim} columns, shards give "
            f"{n}x{ks}")
    r = min(rows, t)
    pad = (-t) % r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_ag_matmul_kernel, nk=n),
        grid=((t + pad) // r, n),
        in_specs=[pl.BlockSpec((r, ks), lambda i, k: (i, k)),
                  pl.BlockSpec((1, ks, nout), lambda i, k: (k, 0, 0))],
        out_specs=pl.BlockSpec((r, nout), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t + pad, nout), x.dtype),
        scratch_shapes=[pltpu.VMEM((r, nout), jnp.float32)],
        interpret=interpret,
    )(x, w_shards)
    return out[:t]


# --------------------------------------------------------------------- #
# differentiable wrapper for the training path
# --------------------------------------------------------------------- #

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def fused_dense(x: jnp.ndarray, w_shards: jnp.ndarray,
                interpret: bool = True) -> jnp.ndarray:
    """``x @ concat(w_shards)`` over the last dim of ``x`` (leading dims
    are batch), forward via :func:`all_gather_matmul`.  Differentiable:
    the VJP is the plain-jnp reference matmul transpose (the fusion win
    is a forward-bandwidth property; the backward pass keeps the
    unfused reference numerics)."""
    return _fused_dense_fwd(x, w_shards, interpret)[0]


def _fused_dense_fwd(x, w_shards, interpret):
    n, ks, nout = w_shards.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = all_gather_matmul(x2, w_shards, interpret=interpret)
    return y.reshape(lead + (nout,)), (x2, w_shards, lead)


def _fused_dense_bwd(interpret, res, g):
    x2, w_shards, lead = res
    n, ks, nout = w_shards.shape
    g2 = g.reshape(-1, nout)
    w = w_shards.reshape(n * ks, nout)
    dx = jax.lax.dot_general(
        g2, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(x2.dtype)
    dw = jax.lax.dot_general(
        x2, g2, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(w_shards.dtype)
    return dx.reshape(lead + (n * ks,)), dw.reshape(n, ks, nout)


fused_dense.defvjp(_fused_dense_fwd, _fused_dense_bwd)
