"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def chunked_reduce_ref(x: jnp.ndarray) -> jnp.ndarray:
    """(n_src, length) -> (length,) sum with f32 accumulation."""
    return jnp.sum(x.astype(jnp.float32), axis=0).astype(x.dtype)


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window=None) -> jnp.ndarray:
    """(BH, L, D) plain softmax attention."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(d)
    lq, lk = q.shape[1], k.shape[1]
    qpos = jnp.arange(lq)[:, None]
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v)


def ssm_scan_ref(x, dt, a, bs, cs, d_res):
    """Sequential reference for the selective scan (f32 state)."""
    bsz, l, d = x.shape
    n = a.shape[1]
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    decay = jnp.exp(dt32[..., None] * a[None, None])      # (B, L, D, N)
    drive = (dt32 * x32)[..., None] * \
        bs.astype(jnp.float32)[:, :, None, :]

    def step(h, inp):
        dec, drv, ct = inp
        h = dec * h + drv
        y = jnp.sum(h * ct[:, None, :], axis=-1)
        return h, y

    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0, (decay.swapaxes(0, 1), drive.swapaxes(0, 1),
                   cs.astype(jnp.float32).swapaxes(0, 1)))
    y = ys.swapaxes(0, 1) + d_res.astype(jnp.float32)[None, None] * x32
    return y.astype(x.dtype)


def rms_norm_ref(x, scale, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps))
            * scale.astype(jnp.float32)).astype(x.dtype)


# -- fused collective+compute oracles (kernels.fused_collectives) --------- #
# Each is the unfused two-step composition the fused kernel replaces:
# sum the peers' partials (f32, the reduce_scatter consumer side), then
# run the epilogue / consuming matmul as a separate pass.

def reduce_scatter_rmsnorm_ref(shards, scale, eps: float = 1e-5):
    """(n_src, T, D) peer partials -> (T, D) rmsnorm of the f32 sum."""
    acc = jnp.sum(shards.astype(jnp.float32), axis=0)
    var = jnp.mean(jnp.square(acc), axis=-1, keepdims=True)
    return ((acc * jax.lax.rsqrt(var + eps))
            * scale.astype(jnp.float32)).astype(shards.dtype)


def reduce_scatter_adamw_ref(shards, p, m, v, lr, bc1, bc2,
                             b1: float = 0.9, b2: float = 0.95,
                             eps: float = 1e-8,
                             weight_decay: float = 0.0):
    """(n_src, L) grad partials + (L,) param/moments -> (p', m', v');
    the ``optim.adamw_update`` math applied to the summed gradient."""
    g = jnp.sum(shards.astype(jnp.float32), axis=0)
    lr32 = jnp.float32(lr)
    m = b1 * m + (1.0 - b1) * g
    v = b2 * v + (1.0 - b2) * jnp.square(g)
    delta = (m / jnp.float32(bc1)) / (jnp.sqrt(v / jnp.float32(bc2))
                                      + eps)
    p32 = p.astype(jnp.float32)
    if weight_decay:
        delta = delta + weight_decay * p32
    return (p32 - lr32 * delta).astype(p.dtype), m, v


def all_gather_matmul_ref(x, w_shards):
    """(T, n*Ks) @ concat((n, Ks, N) shards) with f32 accumulation."""
    n, ks, nout = w_shards.shape
    w = w_shards.reshape(n * ks, nout)
    return jnp.dot(x, w,
                   preferred_element_type=jnp.float32).astype(x.dtype)
