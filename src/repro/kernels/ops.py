"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (the kernel body runs in Python
via the Pallas interpreter - our CPU validation mode) and False on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import chunked_reduce as _cr
from repro.kernels import flash_attention as _fa
from repro.kernels import fused_collectives as _fc
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssm_scan as _ss


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def chunked_reduce(x: jnp.ndarray, tile: int = _cr.DEFAULT_TILE,
                   interpret=None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    return _cr.chunked_reduce(x, tile=tile, interpret=interpret)


def flash_attention(q, k, v, causal: bool = True, window=None,
                    block_q: int = _fa.BLOCK_Q,
                    block_k: int = _fa.BLOCK_K, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


def ssm_scan(x, dt, a, bs, cs, d_res, block_d: int = _ss.BLOCK_D,
             block_l: int = _ss.BLOCK_L, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _ss.ssm_scan(x, dt, a, bs, cs, d_res, block_d=block_d,
                        block_l=block_l, interpret=interpret)


def rms_norm(x, scale, eps: float = 1e-5, rows: int = _rn.ROW_TILE,
             interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rn.rms_norm(x, scale, eps=eps, rows=rows,
                        interpret=interpret)


def reduce_scatter_rmsnorm(shards, scale, eps: float = 1e-5,
                           rows: int = _fc.ROW_TILE, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fc.reduce_scatter_rmsnorm(shards, scale, eps=eps, rows=rows,
                                      interpret=interpret)


def reduce_scatter_adamw(shards, p, m, v, lr, bc1, bc2,
                         b1: float = 0.9, b2: float = 0.95,
                         eps: float = 1e-8, weight_decay: float = 0.0,
                         tile: int = _fc.SEG_TILE, interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fc.reduce_scatter_adamw(shards, p, m, v, lr, bc1, bc2,
                                    b1=b1, b2=b2, eps=eps,
                                    weight_decay=weight_decay,
                                    tile=tile, interpret=interpret)


def all_gather_matmul(x, w_shards, rows: int = _fc.ROW_TILE,
                      interpret=None):
    interpret = _default_interpret() if interpret is None else interpret
    return _fc.all_gather_matmul(x, w_shards, rows=rows,
                                 interpret=interpret)


def fused_dense(x, w_shards, interpret=None):
    """Differentiable fused AllGather-consuming matmul (the FSDP path's
    gather+matmul replacement; see ``fused_collectives.fused_dense``)."""
    interpret = _default_interpret() if interpret is None else interpret
    return _fc.fused_dense(x, w_shards, interpret)
