"""Pallas TPU kernel: fused RMSNorm.

Every decoder row begins with an RMSNorm over d_model; unfused it costs
three HBM passes (square-mean, rsqrt-scale, multiply).  The kernel fuses
them into one read + one write per tile with the f32 variance reduction
in VMEM.  Rows (tokens) tile the grid; d_model stays resident per tile.

x: (T, D), scale: (D,) -> (T, D).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_TILE = 256


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)              # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "rows", "interpret"))
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5,
             rows: int = ROW_TILE, interpret: bool = True) -> jnp.ndarray:
    t, d = x.shape
    r = min(rows, t)
    pad = (-t) % r
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((t + pad) // r,),
        in_specs=[pl.BlockSpec((r, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((r, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((t + pad, d), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:t]
