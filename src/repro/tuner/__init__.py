"""Autotuning plan subsystem (cost-model-driven backend selection).

Offline, ``generate_plan`` sweeps every (primitive, message size, axis
size, slicing factor, allreduce mode) cell through the pool simulator
and the IB alpha-beta model and records the predicted-fastest choice;
with a ``core.topology.Topology`` the sweep runs once per level, each
cell keyed by (level, fabric fingerprint) and priced against that
level's own fabric config.  Online, ``Communicator(backend="auto")``
consults the persisted plan at trace time and the ledger audits every
decision taken.

Workflow (topology axis names must match the mesh axes the launcher
builds - the launchers warn on uncovered axes)::

    python -m repro.launch.tune --topology "pod:ib,data:cxl,model:ici" \
        --out plan.json                             # offline, per level
    python -m repro.launch.train --backend auto --plan plan.json \
        --multi-pod --online-retune --plan-out refined.json

With ``--online-retune`` the launcher measures step wall times, folds
them into the plan as per-cell EWMAs (``tuner.online``), and hot-swaps
the refreshed plan through the epoch-versioned active-plan registry at
``--retune-interval`` boundaries; ``--plan-out`` persists the refined
(format v4) plan for the next run.

``tuner.placement`` chooses the mesh-axis -> fabric-level assignment
itself: ``plan_placement(CollectiveMix, Topology)`` ranks every
feasible assignment (axis splits across adjacent levels, irregular
shape-vector levels priced by their grouped decomposition) by
predicted exposed step time, and launchers apply the winner with
``--placement auto`` (``tune --placement-report`` embeds the ranked
table in the plan metadata, ``Plan.placement()`` reads it back).

See ``docs/API.md`` for the public-surface reference and
``docs/ARCHITECTURE.md`` for how the pieces fit.
"""
from repro.tuner.costmodel import (ici_time, predict_exposed_time,
                                   predict_level_p2p_time,
                                   predict_level_time, predict_p2p_time,
                                   predict_time, roofline_compute_time)
from repro.tuner.online import (OnlineTuner, choices_changed,
                                fold_measurements)
from repro.tuner.placement import (AxisTraffic, CollectiveCall,
                                   CollectiveMix, Placement,
                                   PlacementPlan, format_report,
                                   load_placement, mesh_spec,
                                   placed_topology, plan_placement,
                                   predict_call_time, save_placement)
from repro.tuner.plan import (Choice, Plan, PlanVersionError,
                              hardware_fingerprint, load_plan, save_plan,
                              size_bucket)
from repro.tuner.runtime import (activate_plan_file, clear_active_plan,
                                 default_plan_path, ensure_default_plan,
                                 get_active_plan,
                                 get_active_plan_versioned, plan_epoch,
                                 set_active_plan)
from repro.tuner.sweep import (DEFAULT_GRID, SMOKE_GRID, TuneGrid,
                               generate_plan, overlap_windows_from_dryrun)

__all__ = [
    "Choice", "Plan", "PlanVersionError", "TuneGrid", "DEFAULT_GRID",
    "SMOKE_GRID",
    "predict_time", "predict_exposed_time", "predict_level_time",
    "predict_p2p_time", "predict_level_p2p_time",
    "ici_time", "roofline_compute_time",
    "generate_plan", "overlap_windows_from_dryrun",
    "hardware_fingerprint",
    "size_bucket", "load_plan", "save_plan", "activate_plan_file",
    "clear_active_plan", "default_plan_path", "ensure_default_plan",
    "get_active_plan", "get_active_plan_versioned", "plan_epoch",
    "set_active_plan",
    "OnlineTuner", "choices_changed", "fold_measurements",
    "AxisTraffic", "CollectiveCall", "CollectiveMix", "Placement",
    "PlacementPlan", "plan_placement", "placed_topology", "mesh_spec",
    "predict_call_time",
    "format_report", "save_placement", "load_placement",
]
