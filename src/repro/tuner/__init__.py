"""Autotuning plan subsystem (cost-model-driven backend selection).

Offline, ``generate_plan`` sweeps every (primitive, message size, axis
size, slicing factor, allreduce mode) cell through the pool simulator
and the IB alpha-beta model and records the predicted-fastest choice.
Online, ``Communicator(backend="auto")`` consults the persisted plan at
trace time and the ledger audits every decision taken.

Workflow::

    python -m repro.launch.tune --out plan.json     # offline
    python -m repro.launch.train --backend auto --plan plan.json
"""
from repro.tuner.costmodel import (predict_exposed_time, predict_time,
                                   roofline_compute_time)
from repro.tuner.plan import (Choice, Plan, hardware_fingerprint,
                              load_plan, save_plan, size_bucket)
from repro.tuner.runtime import (activate_plan_file, clear_active_plan,
                                 default_plan_path, ensure_default_plan,
                                 get_active_plan, set_active_plan)
from repro.tuner.sweep import (DEFAULT_GRID, SMOKE_GRID, TuneGrid,
                               generate_plan)

__all__ = [
    "Choice", "Plan", "TuneGrid", "DEFAULT_GRID", "SMOKE_GRID",
    "predict_time", "predict_exposed_time", "roofline_compute_time",
    "generate_plan", "hardware_fingerprint",
    "size_bucket", "load_plan", "save_plan", "activate_plan_file",
    "clear_active_plan", "default_plan_path", "ensure_default_plan",
    "get_active_plan", "set_active_plan",
]
