"""Autotuning plan: per-(primitive, size-bucket, nranks) backend choice.

A ``Plan`` is the persisted product of an offline sweep through the two
cost oracles (``core.simulator`` for the pool backend, ``core.ibmodel``
for the NCCL-over-IB baseline).  Each entry maps

    (primitive, floor(log2(msg_bytes)), nranks)
        -> Choice(backend, slicing_factor, allreduce_mode, ...)

and ``Communicator(backend="auto")`` consults it at trace time (shapes
are static, so the lookup costs nothing at run time).  Plans are keyed
by a fingerprint of the hardware model (``CXLPoolConfig`` +
``InfiniBandConfig``): a plan tuned for one pool must not silently drive
another.

Lookup is log2-bucketed with nearest-bucket fallback: an unseen message
size resolves to the closest tuned bucket (ties to the smaller), and an
unseen rank count to the closest tuned nranks for that primitive.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)

PLAN_VERSION = 2          # v2 adds per-cell overlap fields (v1 loads too)
_READABLE_VERSIONS = (1, 2)


def hardware_fingerprint(pool: CXLPoolConfig = CXL_POOL,
                         ib: InfiniBandConfig = INFINIBAND) -> str:
    blob = json.dumps({"pool": dataclasses.asdict(pool),
                       "ib": dataclasses.asdict(ib)}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def size_bucket(nbytes: int) -> int:
    """floor(log2(nbytes)); bucket 0 holds 1-byte messages."""
    n = int(nbytes)
    if n < 1:
        raise ValueError("message size must be >= 1 byte")
    return n.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class Choice:
    """The knobs the tuner picked for one (primitive, bucket, nranks)."""

    backend: str                       # 'ring' | 'cxl'
    slicing_factor: int = 4
    allreduce_mode: str = "two_phase"
    predicted_time: float = 0.0        # cost-model time of this choice
                                       # (exposed time when overlap-tuned)
    baseline_time: float = 0.0         # best fixed-knob alternative
    # Overlap-aware costing (ROADMAP "overlap-aware costing"): when the
    # cell was tuned against the compute it overlaps, ``overlap`` is True
    # and ``hidden_time`` is the wire time the roofline-residency model
    # expects compute to hide (exposed = wire - hidden).
    overlap: bool = False
    hidden_time: float = 0.0


PlanKey = tuple  # (primitive, bucket, nranks)


@dataclasses.dataclass
class Plan:
    fingerprint: str
    entries: dict = dataclasses.field(default_factory=dict)  # key -> Choice
    meta: dict = dataclasses.field(default_factory=dict)

    def add(self, primitive: str, msg_bytes: int, nranks: int,
            choice: Choice) -> None:
        self.entries[(primitive, size_bucket(msg_bytes), nranks)] = choice

    def matches(self, pool: CXLPoolConfig = CXL_POOL,
                ib: InfiniBandConfig = INFINIBAND) -> bool:
        return self.fingerprint == hardware_fingerprint(pool, ib)

    def lookup(self, primitive: str, msg_bytes: int,
               nranks: int) -> Optional[Choice]:
        """Nearest-bucket plan lookup (None if the primitive is untuned)."""
        keys = [k for k in self.entries if k[0] == primitive]
        if not keys:
            return None
        want_b = size_bucket(max(1, msg_bytes))
        # Nearest tuned nranks first (ties to the smaller) ...
        best_n = min({k[2] for k in keys},
                     key=lambda n: (abs(n - nranks), n))
        # ... then the nearest tuned bucket within that nranks.
        best_b = min({k[1] for k in keys if k[2] == best_n},
                     key=lambda b: (abs(b - want_b), b))
        return self.entries[(primitive, best_b, best_n)]

    # -- serialization ----------------------------------------------------

    def to_json(self) -> dict:
        return {
            "version": PLAN_VERSION,
            "fingerprint": self.fingerprint,
            "meta": self.meta,
            "entries": [
                {"primitive": k[0], "bucket": k[1], "nranks": k[2],
                 **dataclasses.asdict(c)}
                for k, c in sorted(self.entries.items())],
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Plan":
        if doc.get("version") not in _READABLE_VERSIONS:
            raise ValueError(
                f"unsupported plan version {doc.get('version')!r}")
        plan = cls(fingerprint=doc["fingerprint"],
                   meta=dict(doc.get("meta", {})))
        for e in doc["entries"]:
            key = (e["primitive"], int(e["bucket"]), int(e["nranks"]))
            plan.entries[key] = Choice(
                backend=e["backend"],
                slicing_factor=int(e["slicing_factor"]),
                allreduce_mode=e["allreduce_mode"],
                predicted_time=float(e["predicted_time"]),
                baseline_time=float(e["baseline_time"]),
                # v1 plans carry no overlap fields: cost-in-isolation
                overlap=bool(e.get("overlap", False)),
                hidden_time=float(e.get("hidden_time", 0.0)))
        return plan


def save_plan(plan: Plan, path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(plan.to_json(), f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_plan(path: str, *, pool: Optional[CXLPoolConfig] = None,
              ib: Optional[InfiniBandConfig] = None) -> Plan:
    """Load a plan; when ``pool``/``ib`` are given, refuse a plan tuned
    for different hardware."""
    with open(path) as f:
        plan = Plan.from_json(json.load(f))
    if pool is not None or ib is not None:
        want = hardware_fingerprint(pool or CXL_POOL, ib or INFINIBAND)
        if plan.fingerprint != want:
            raise ValueError(
                f"plan {path} was tuned for hardware {plan.fingerprint}, "
                f"current config fingerprints to {want}")
    return plan
