"""Autotuning plan: per-(primitive, size-bucket, nranks[, level]) choice.

A ``Plan`` is the persisted product of an offline sweep through the
cost oracles (``core.simulator`` for the pool backend, ``core.ibmodel``
for the NCCL-over-IB baseline, the ICI alpha-beta model for intra-node
rings).  Each entry maps

    (primitive, floor(log2(msg_bytes)), nranks[, level])
        -> Choice(backend, slicing_factor, allreduce_mode, ...)

and ``Communicator(backend="auto")`` consults it at trace time (shapes
are static, so the lookup costs nothing at run time).

Plans are keyed by a hardware fingerprint: for flat plans a hash of
``CXLPoolConfig`` + ``InfiniBandConfig``; for topology plans (format
v3) the ``Topology.fingerprint()`` - and every cell additionally
carries its level key ``"<level index>:<fabric fingerprint>"`` so a
cell tuned for the rack-scale pool never drives the cross-pod IB
level.  The topology itself rides in ``meta["topology"]`` so
``tune -> train`` round-trips through one JSON file.

Format v4 closes the loop on the offline oracles: cells additionally
carry ``measured_us``/``sample_count``/``ewma_alpha``, the
exponentially-weighted measured wall time that ``tuner.online`` folds
back into the plan from ledger-tagged timing samples.  A refreshed
plan's ``measured_us`` overrides the simulator prediction as the
cell's cost (``Choice.effective_time``) once enough samples landed.

Format v5 adds the ``fused`` knob: a cell tuned fused expects the
collective's epilogue/prologue compute to run inside a fused Pallas
kernel (``kernels.fused_collectives``), which the sweep prices by
folding the epilogue roofline into the cell's overlap window.

Format v6 adds point-to-point cells: ``("p2p", bucket, nranks, level)``
entries tune the pipeline stage handoff (``Communicator.send``) -
backend ``cxl`` is the pool write + doorbell commit, ``ring`` the
direct NIC/ICI hop - with the slicing factor pipelining the consumer
read behind the producer write on the pool.

Lookup is log2-bucketed with nearest-bucket fallback: an unseen message
size resolves to the closest tuned bucket (ties to the smaller), an
unseen rank count to the closest tuned nranks for that primitive, and
a level-keyed lookup falls back to the plan's level-agnostic cells when
the level is untuned.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Optional

from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)
from repro.core.topology import Topology

PLAN_VERSION = 6          # v6 adds point-to-point (pipeline) cells
_READABLE_VERSIONS = (1, 2, 3, 4, 5, 6)
# v1: flat cells only; v2: + per-cell overlap fields; v3: + level keys;
# v4: + measured_us/sample_count/ewma_alpha (online re-tuning feedback);
# v5: + fused (epilogue/prologue folded into a fused collective+compute
# kernel, kernels.fused_collectives);
# v6: + "p2p" point-to-point cells (pipeline stage handoff, tuned per
# (size bucket, level): cxl pool-write+doorbell vs direct ring hop).
# Older formats load forward (missing fields default); unknown formats
# raise PlanVersionError.


class PlanVersionError(ValueError):
    """A plan JSON uses a format version this build cannot read."""


def hardware_fingerprint(pool: CXLPoolConfig = CXL_POOL,
                         ib: InfiniBandConfig = INFINIBAND) -> str:
    blob = json.dumps({"pool": dataclasses.asdict(pool),
                       "ib": dataclasses.asdict(ib)}, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def size_bucket(nbytes: int) -> int:
    """floor(log2(nbytes)); bucket 0 holds 1-byte messages."""
    n = int(nbytes)
    if n < 1:
        raise ValueError("message size must be >= 1 byte")
    return n.bit_length() - 1


@dataclasses.dataclass(frozen=True)
class Choice:
    """The knobs the tuner picked for one (primitive, bucket, nranks)."""

    backend: str                       # 'ring' | 'cxl'
    slicing_factor: int = 4
    allreduce_mode: str = "two_phase"
    predicted_time: float = 0.0        # cost-model time of this choice
                                       # (exposed time when overlap-tuned)
    baseline_time: float = 0.0         # best fixed-knob alternative
    # Overlap-aware costing (ROADMAP "overlap-aware costing"): when the
    # cell was tuned against the compute it overlaps, ``overlap`` is True
    # and ``hidden_time`` is the wire time the roofline-residency model
    # expects compute to hide (exposed = wire - hidden).
    overlap: bool = False
    hidden_time: float = 0.0
    # Online re-tuning feedback (plan format v4): ``measured_us`` is
    # the exponentially-weighted mean (microseconds, smoothing factor
    # ``ewma_alpha``) of the ``sample_count`` ledger-tagged wall-time
    # measurements of the *chosen* candidate, persisted by
    # ``tuner.online.OnlineTuner.refresh`` (which re-resolves cells by
    # comparing its live per-candidate EWMAs against the oracle) so a
    # saved plan warm-starts the next run's tuner.  Zero-sample cells
    # are purely offline.
    measured_us: float = 0.0
    sample_count: int = 0
    ewma_alpha: float = 0.0
    # Fused collective+compute kernel (plan format v5): True when the
    # cell was priced with the collective's epilogue/prologue folded
    # into a fused Pallas kernel (``kernels.fused_collectives``) - the
    # epilogue roofline widens the overlap window, and the training
    # stack realizes the fusion via ``TrainConfig.fuse_kernels``.
    fused: bool = False

    def effective_time(self, min_samples: int = 1) -> float:
        """The cell's best per-launch cost estimate in seconds: the
        persisted measured EWMA once ``min_samples`` samples backed it,
        else the oracle prediction.  ``Communicator(backend='auto')``
        prices its audit entries with this, so step-time apportioning
        and dry-run deltas see measured reality on refined plans."""
        if self.sample_count >= max(1, min_samples) and \
                self.measured_us > 0.0:
            return self.measured_us * 1e-6
        return self.predicted_time


PlanKey = tuple  # (primitive, bucket, nranks) or (..., level)


@dataclasses.dataclass
class Plan:
    """The persisted product of a tuning sweep: a mapping from
    ``(primitive, log2-size bucket, nranks[, level key])`` to the
    :class:`Choice` the cost model picked, plus the hardware
    ``fingerprint`` it was tuned for and free-form ``meta`` (the grid,
    the embedded topology for per-level plans, the overlap objective,
    an optional placement report).  Build one with
    ``tuner.generate_plan``; persist with ``save_plan`` /
    ``load_plan``; serve it process-wide with
    ``tuner.activate_plan_file`` so ``Communicator(backend='auto')``
    resolves against it at trace time."""

    fingerprint: str
    entries: dict = dataclasses.field(default_factory=dict)  # key -> Choice
    meta: dict = dataclasses.field(default_factory=dict)

    def add(self, primitive: str, msg_bytes: int, nranks: int,
            choice: Choice, level: Optional[str] = None) -> None:
        key = (primitive, size_bucket(msg_bytes), nranks)
        if level is not None:
            key = key + (level,)
        self.entries[key] = choice

    def matches(self, pool: CXLPoolConfig = CXL_POOL,
                ib: InfiniBandConfig = INFINIBAND) -> bool:
        return self.fingerprint == hardware_fingerprint(pool, ib)

    def topology(self) -> Optional[Topology]:
        """The Topology this plan was tuned for (None for flat plans)."""
        doc = self.meta.get("topology")
        return Topology.from_json(doc) if doc else None

    def placement(self):
        """The ranked ``tuner.placement.PlacementPlan`` embedded by
        ``launch/tune --placement-report`` (None when the plan was
        tuned without one).  Lives in ``meta`` so one JSON file carries
        sweep + topology + placement through ``tune -> train``."""
        doc = self.meta.get("placement")
        if not doc:
            return None
        from repro.tuner.placement import PlacementPlan
        return PlacementPlan.from_json(doc)

    def calibration(self) -> dict:
        """The learned measured/oracle calibration table persisted by
        ``tuner.online.OnlineTuner.refresh`` (see
        ``calibration_export``): ``{"scales": [...], "levels": [...]}``
        with per-(backend, level, primitive) pricing scales and the
        per-(backend, level) aggregate that ``obs.health`` reads as a
        fabric-drift signal.  Empty dict when the plan carries no
        measurements.  Free-form ``meta`` keys load under every
        readable plan version, so no format bump is needed."""
        return dict(self.meta.get("calibration") or {})

    def levels(self) -> tuple:
        """Distinct level keys appearing in the plan's cells."""
        return tuple(sorted({k[3] for k in self.entries if len(k) == 4}))

    def lookup(self, primitive: str, msg_bytes: int, nranks: int,
               level: Optional[str] = None) -> Optional[Choice]:
        """Nearest-bucket plan lookup (None if the primitive is untuned).

        With ``level``, only cells tuned for that (level index, fabric
        fingerprint) match; when the level is untuned the lookup falls
        back to the plan's level-agnostic cells."""
        keys = [k for k in self.entries
                if k[0] == primitive and len(k) == 4 and k[3] == level] \
            if level is not None else []
        if not keys:
            keys = [k for k in self.entries
                    if k[0] == primitive and len(k) == 3]
        if not keys:
            return None
        want_b = size_bucket(max(1, msg_bytes))
        # Nearest tuned nranks first (ties to the smaller) ...
        best_n = min({k[2] for k in keys},
                     key=lambda n: (abs(n - nranks), n))
        # ... then the nearest tuned bucket within that nranks.
        best_b = min({k[1] for k in keys if k[2] == best_n},
                     key=lambda b: (abs(b - want_b), b))
        for k in keys:
            if k[1] == best_b and k[2] == best_n:
                return self.entries[k]
        return None

    # -- serialization ----------------------------------------------------

    def to_json(self) -> dict:
        entries = []
        for k, c in sorted(self.entries.items(),
                           key=lambda kv: (kv[0][0], kv[0][1], kv[0][2],
                                           kv[0][3] if len(kv[0]) == 4
                                           else "")):
            doc = {"primitive": k[0], "bucket": k[1], "nranks": k[2],
                   **dataclasses.asdict(c)}
            if len(k) == 4:
                doc["level"] = k[3]
            entries.append(doc)
        return {
            "version": PLAN_VERSION,
            "fingerprint": self.fingerprint,
            "meta": self.meta,
            "entries": entries,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "Plan":
        version = doc.get("version")
        if version not in _READABLE_VERSIONS:
            raise PlanVersionError(
                f"unsupported plan format version {version!r}; this "
                f"build reads versions {_READABLE_VERSIONS} "
                f"(current: {PLAN_VERSION}) - re-run repro.launch.tune "
                f"to regenerate the plan")
        plan = cls(fingerprint=doc["fingerprint"],
                   meta=dict(doc.get("meta", {})))
        for e in doc["entries"]:
            key = (e["primitive"], int(e["bucket"]), int(e["nranks"]))
            if e.get("level") is not None:   # v3 level-keyed cell
                key = key + (str(e["level"]),)
            plan.entries[key] = Choice(
                backend=e["backend"],
                slicing_factor=int(e["slicing_factor"]),
                allreduce_mode=e["allreduce_mode"],
                predicted_time=float(e["predicted_time"]),
                baseline_time=float(e["baseline_time"]),
                # v1 plans carry no overlap fields: cost-in-isolation
                overlap=bool(e.get("overlap", False)),
                hidden_time=float(e.get("hidden_time", 0.0)),
                # pre-v4 plans carry no measured feedback: offline-only
                measured_us=float(e.get("measured_us", 0.0)),
                sample_count=int(e.get("sample_count", 0)),
                ewma_alpha=float(e.get("ewma_alpha", 0.0)),
                # pre-v5 plans carry no fusion knob: unfused
                fused=bool(e.get("fused", False)))
        return plan


def save_plan(plan: Plan, path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(plan.to_json(), f, indent=1, sort_keys=True)
    os.replace(tmp, path)


def load_plan(path: str, *, pool: Optional[CXLPoolConfig] = None,
              ib: Optional[InfiniBandConfig] = None,
              topology: Optional[Topology] = None) -> Plan:
    """Load a plan; when ``pool``/``ib``/``topology`` are given, refuse a
    plan tuned for different hardware.  Topology plans carry their own
    per-level fabric configs, so the flat pool/ib check only applies to
    flat plans."""
    with open(path) as f:
        plan = Plan.from_json(json.load(f))
    plan_topo = plan.topology()
    if topology is not None:
        want = topology.fingerprint()
        if plan.fingerprint != want:
            raise ValueError(
                f"plan {path} was tuned for topology {plan.fingerprint}, "
                f"current topology fingerprints to {want}")
        return plan
    if plan_topo is not None:
        if plan.fingerprint != plan_topo.fingerprint():
            raise ValueError(
                f"plan {path} is corrupt: fingerprint "
                f"{plan.fingerprint} does not match its embedded "
                f"topology ({plan_topo.fingerprint()})")
        return plan
    if pool is not None or ib is not None:
        want = hardware_fingerprint(pool or CXL_POOL, ib or INFINIBAND)
        if plan.fingerprint != want:
            raise ValueError(
                f"plan {path} was tuned for hardware {plan.fingerprint}, "
                f"current config fingerprints to {want}")
    return plan
