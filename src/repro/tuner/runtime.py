"""Process-wide plan registry + the on-disk plan cache.

``Communicator(backend="auto")`` resolves its plan in this order:

1. the ``plan`` explicitly attached to the Communicator;
2. the process-wide active plan (``set_active_plan`` /
   ``activate_plan_file``);
3. the persisted default plan for the current hardware fingerprint
   (``ensure_default_plan``), generated on first use with the smoke
   grid and cached under ``$REPRO_PLAN_CACHE`` (default
   ``~/.cache/repro/plans``) so later processes just load it.

Topology plans (format v3) are fingerprinted by the topology, and
``activate_plan_file`` also activates the embedded topology
(``core.topology.set_active_topology``) so every Communicator in the
process decomposes tuple axes against the levels the plan was tuned
for - one ``--plan`` flag wires up the whole tune -> train workflow.

The registry is *epoch-versioned* for online re-tuning: every
``set_active_plan`` bumps a monotonically increasing epoch, and
``Communicator(backend='auto')`` stamps the epoch it resolved against
into the ledger audit.  Hot-swapping a refreshed plan between steps is
therefore just ``set_active_plan(new_plan)`` + re-tracing the step -
per-call resolution always reads the registry, no plan state is baked
into the Communicator itself.
"""
from __future__ import annotations

import os
import warnings
from typing import Optional

from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)
from repro.core.topology import (Topology, get_active_topology,
                                 set_active_topology)
from repro.tuner.plan import (Plan, hardware_fingerprint, load_plan,
                              save_plan)
from repro.tuner.sweep import SMOKE_GRID, TuneGrid, generate_plan

_ACTIVE: list = [None]
_EPOCH: list = [0]


def set_active_plan(plan: Optional[Plan]) -> None:
    _ACTIVE[0] = plan
    _EPOCH[0] += 1


def get_active_plan() -> Optional[Plan]:
    return _ACTIVE[0]


def plan_epoch() -> int:
    """Monotonic version of the active-plan registry: bumps on every
    ``set_active_plan`` / ``clear_active_plan``, so a consumer can tell
    whether the plan it resolved against is still current."""
    return _EPOCH[0]


def get_active_plan_versioned() -> tuple:
    """(active plan, registry epoch) - the pair ``backend='auto'``
    resolution reads, so audits can attribute each decision to the plan
    generation that produced it."""
    return _ACTIVE[0], _EPOCH[0]


def clear_active_plan() -> None:
    set_active_plan(None)


# -- link-health registry (obs.health publishes, planners consult) ---------
#
# Keyed "<level axis>/<fabric>" -> the health monitor's latest state for
# that link ({"degraded", "slowdown", "since_step", ...}).  Lives next
# to the active plan because it is plan-shaped advice: a degraded entry
# tells a planner (or a human reading the dry-run report) that the
# measured fabric no longer matches what the plan was tuned against.

_LINK_HEALTH: dict = {}


def set_link_health(key: str, state: dict) -> None:
    _LINK_HEALTH[str(key)] = dict(state)


def get_link_health(key: "str | None" = None):
    """One link's state dict (or None), or a copy of the whole registry
    when called without a key."""
    if key is None:
        return {k: dict(v) for k, v in _LINK_HEALTH.items()}
    return _LINK_HEALTH.get(str(key))


def degraded_links() -> list:
    return sorted(k for k, v in _LINK_HEALTH.items()
                  if v.get("degraded"))


def clear_link_health() -> None:
    _LINK_HEALTH.clear()


# -- rank-liveness registry (resilience.FailureMonitor publishes) ----------
#
# Keyed by global rank -> the monitor's latest verdict for that rank
# ({"alive", "last_beat_step", "confirmed_step", ...}).  Mirrors the
# link-health registry: plan-shaped advice living next to the plan,
# consulted by planners deriving survivor topologies and by launchers
# deciding whether a re-plan is due.

_RANK_LIVENESS: dict = {}


def set_rank_liveness(rank: int, state: dict) -> None:
    _RANK_LIVENESS[int(rank)] = dict(state)


def get_rank_liveness(rank: "int | None" = None):
    """One rank's state dict (or None), or a copy of the whole registry
    when called without a rank."""
    if rank is None:
        return {r: dict(v) for r, v in _RANK_LIVENESS.items()}
    return _RANK_LIVENESS.get(int(rank))


def dead_ranks() -> list:
    """Ranks the failure monitor has *confirmed* dead, sorted."""
    return sorted(r for r, v in _RANK_LIVENESS.items()
                  if not v.get("alive", True))


def clear_rank_liveness() -> None:
    _RANK_LIVENESS.clear()


def activate_plan_file(path: str, *,
                       pool: Optional[CXLPoolConfig] = None,
                       ib: Optional[InfiniBandConfig] = None,
                       topology: Optional[Topology] = None) -> Plan:
    """Load a plan file, fingerprint-check it against the given
    hardware (``pool``/``ib`` for flat plans, ``topology`` for
    per-level ones), publish it as the process-wide active plan, and
    activate its embedded topology when no explicit one is set - the
    single call that wires ``tune -> train`` together.  Returns the
    activated Plan; raises ``ValueError`` on a fingerprint mismatch
    and ``PlanVersionError`` on an unreadable format."""
    plan = load_plan(path, pool=pool, ib=ib, topology=topology)
    set_active_plan(plan)
    topo = plan.topology()
    if topo is not None:
        # An explicitly activated topology wins over the plan's embedded
        # one, but a mismatch means the plan's level keys will never
        # resolve - surface that instead of silently ringing everything.
        current = get_active_topology()
        if current is None:
            set_active_topology(topo)
        elif current.fingerprint() != topo.fingerprint():
            # Name BOTH fingerprints (and each side's level layout):
            # with only one of them in the log line there is no way to
            # tell from logs which of the two topologies a stray plan
            # actually belongs to.
            warnings.warn(
                f"topology conflict: the active topology fingerprints "
                f"to {current.fingerprint()} (levels "
                f"{[f'{lv.axis}:{lv.fabric}' for lv in current.levels]})"
                f" but plan {path} was tuned for topology "
                f"{topo.fingerprint()} (levels "
                f"{[f'{lv.axis}:{lv.fabric}' for lv in topo.levels]}); "
                f"the plan's level-keyed cells will not resolve and "
                f"collectives fall back to ring")
    return plan


def plan_cache_dir() -> str:
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "plans")


def default_plan_path(pool: CXLPoolConfig = CXL_POOL,
                      ib: InfiniBandConfig = INFINIBAND,
                      topology: Optional[Topology] = None) -> str:
    fp = topology.fingerprint() if topology is not None else \
        hardware_fingerprint(pool, ib)
    return os.path.join(plan_cache_dir(), f"plan_{fp}.json")


def ensure_default_plan(pool: CXLPoolConfig = CXL_POOL,
                        ib: InfiniBandConfig = INFINIBAND,
                        grid: TuneGrid = SMOKE_GRID,
                        topology: Optional[Topology] = None) -> Plan:
    """Return the active plan, loading or generating+persisting the
    fingerprint-keyed default when none is set.  With a topology the
    default plan is tuned per level against each level's own fabric."""
    active = get_active_plan()
    if active is not None:
        return active
    path = default_plan_path(pool, ib, topology=topology)
    if os.path.exists(path):
        try:
            plan = load_plan(path, pool=pool, ib=ib, topology=topology)
            set_active_plan(plan)
            return plan
        except (ValueError, OSError, KeyError):
            pass  # stale/corrupt cache: regenerate below
    plan = generate_plan(grid, pool=pool, ib=ib, topology=topology)
    try:
        save_plan(plan, path)
    except OSError:
        pass  # read-only cache dir: keep the in-memory plan
    set_active_plan(plan)
    return plan
