"""Process-wide plan registry + the on-disk plan cache.

``Communicator(backend="auto")`` resolves its plan in this order:

1. the ``plan`` explicitly attached to the Communicator;
2. the process-wide active plan (``set_active_plan`` /
   ``activate_plan_file``);
3. the persisted default plan for the current hardware fingerprint
   (``ensure_default_plan``), generated on first use with the smoke
   grid and cached under ``$REPRO_PLAN_CACHE`` (default
   ``~/.cache/repro/plans``) so later processes just load it.
"""
from __future__ import annotations

import os
from typing import Optional

from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)
from repro.tuner.plan import (Plan, hardware_fingerprint, load_plan,
                              save_plan)
from repro.tuner.sweep import SMOKE_GRID, TuneGrid, generate_plan

_ACTIVE: list = [None]


def set_active_plan(plan: Optional[Plan]) -> None:
    _ACTIVE[0] = plan


def get_active_plan() -> Optional[Plan]:
    return _ACTIVE[0]


def clear_active_plan() -> None:
    _ACTIVE[0] = None


def activate_plan_file(path: str, *,
                       pool: Optional[CXLPoolConfig] = None,
                       ib: Optional[InfiniBandConfig] = None) -> Plan:
    plan = load_plan(path, pool=pool, ib=ib)
    set_active_plan(plan)
    return plan


def plan_cache_dir() -> str:
    env = os.environ.get("REPRO_PLAN_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "plans")


def default_plan_path(pool: CXLPoolConfig = CXL_POOL,
                      ib: InfiniBandConfig = INFINIBAND) -> str:
    return os.path.join(plan_cache_dir(),
                        f"plan_{hardware_fingerprint(pool, ib)}.json")


def ensure_default_plan(pool: CXLPoolConfig = CXL_POOL,
                        ib: InfiniBandConfig = INFINIBAND,
                        grid: TuneGrid = SMOKE_GRID) -> Plan:
    """Return the active plan, loading or generating+persisting the
    fingerprint-keyed default when none is set."""
    active = get_active_plan()
    if active is not None:
        return active
    path = default_plan_path(pool, ib)
    if os.path.exists(path):
        try:
            plan = load_plan(path, pool=pool, ib=ib)
            set_active_plan(plan)
            return plan
        except (ValueError, OSError, KeyError):
            pass  # stale/corrupt cache: regenerate below
    plan = generate_plan(grid, pool=pool, ib=ib)
    try:
        save_plan(plan, path)
    except OSError:
        pass  # read-only cache dir: keep the in-memory plan
    set_active_plan(plan)
    return plan
