"""Offline tuning sweep: grid -> Plan.

For every (primitive, msg_bytes, nranks) cell the sweep costs a fixed
``ring`` candidate plus every (slicing_factor, allreduce_mode) ``cxl``
candidate, and records the argmin as the plan entry.  The best
*fixed-knob* alternative (ring, or cxl at the Communicator defaults) is
stored alongside, so benchmarks can report regret: by construction the
chosen time is never worse than that baseline as long as the grid
contains the default slicing factor.

``overlap_compute`` turns the sweep overlap-aware: every candidate
(including the fixed baselines, so the regret guarantee survives) is
priced by its *exposed* time ``max(0, comm - overlappable_compute)``
instead of its in-isolation time, where the overlappable window is
either a constant (seconds) or a per-cell callable
``(primitive, msg_bytes, nranks) -> seconds`` (typically a roofline
residency of the layer compute the collective is prefetched behind).
Cells tuned this way carry ``overlap=True`` + the hidden wire time, and
``Communicator(backend='auto')`` books their bytes as overlap-hidden in
the ledger.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Optional, Union

from repro.core import mesh_collectives as mc
from repro.core.hw import (CXL_POOL, INFINIBAND, MiB, CXLPoolConfig,
                           InfiniBandConfig)
from repro.core.schedule import PRIMITIVES
from repro.tuner import costmodel
from repro.tuner.plan import Choice, Plan, hardware_fingerprint


@dataclasses.dataclass(frozen=True)
class TuneGrid:
    primitives: tuple = PRIMITIVES
    sizes: tuple = tuple(m * MiB for m in (1, 4, 16, 64, 256, 1024, 4096))
    nranks: tuple = (2, 3, 4, 6, 8, 12)
    slicing_factors: tuple = (1, 2, 4, 8, 16)
    allreduce_modes: tuple = ("two_phase", "faithful")

    @property
    def cells(self) -> int:
        return len(self.primitives) * len(self.sizes) * len(self.nranks)


DEFAULT_GRID = TuneGrid()

# Grid for the lazy ``ensure_default_plan`` path and CI smoke runs:
# coarse knobs, same coverage shape, seconds not minutes.
SMOKE_GRID = TuneGrid(sizes=tuple(m * MiB for m in (1, 16, 256)),
                      nranks=(2, 3), slicing_factors=(1, 4))


def _candidates(primitive: str, grid: TuneGrid):
    yield ("ring", mc.DEFAULT_CHUNKS, "two_phase")
    modes = grid.allreduce_modes if primitive == "all_reduce" \
        else ("two_phase",)
    for f, m in itertools.product(grid.slicing_factors, modes):
        yield ("cxl", f, m)


OverlapCompute = Union[float, Callable[[str, int, int], float], None]


def generate_plan(grid: TuneGrid = DEFAULT_GRID, *,
                  pool: CXLPoolConfig = CXL_POOL,
                  ib: InfiniBandConfig = INFINIBAND,
                  overlap_compute: OverlapCompute = None,
                  progress: Optional[Callable[[str], None]] = None) -> Plan:
    overlap_meta = ("per-cell" if callable(overlap_compute)
                    else float(overlap_compute or 0.0))
    plan = Plan(fingerprint=hardware_fingerprint(pool, ib),
                meta={"grid": dataclasses.asdict(grid),
                      "overlap_compute_s": overlap_meta})
    for prim in grid.primitives:
        for n in grid.nranks:
            for size in grid.sizes:
                window = 0.0
                if callable(overlap_compute):
                    window = max(0.0, overlap_compute(prim, size, n))
                elif overlap_compute:
                    window = max(0.0, float(overlap_compute))
                best: Optional[Choice] = None
                fixed_best = math.inf
                for backend, factor, mode in _candidates(prim, grid):
                    t_wire = costmodel.predict_time(
                        backend, prim, n, size, slicing_factor=factor,
                        allreduce_mode=mode, pool=pool, ib=ib)
                    # objective: exposed time under the overlap window
                    # (== t_wire when no window); the window applies to
                    # every candidate, fixed baselines included, so the
                    # never-slower-than-fixed guarantee is preserved.
                    t = max(0.0, t_wire - window)
                    if backend == "ring" or (
                            factor == mc.DEFAULT_CHUNKS
                            and mode == "two_phase"):
                        fixed_best = min(fixed_best, t)
                    if best is None or t < best.predicted_time:
                        best = Choice(backend=backend,
                                      slicing_factor=factor,
                                      allreduce_mode=mode,
                                      predicted_time=t,
                                      overlap=window > 0.0,
                                      hidden_time=min(t_wire, window))
                best = dataclasses.replace(best, baseline_time=fixed_best)
                plan.add(prim, size, n, best)
            if progress:
                progress(f"tuned {prim} nranks={n}")
    return plan
