"""Offline tuning sweep: grid -> Plan.

For every (primitive, msg_bytes, nranks) cell the sweep costs a fixed
``ring`` candidate plus every (slicing_factor, allreduce_mode) ``cxl``
candidate, and records the argmin as the plan entry.  The best
*fixed-knob* alternative (ring, or cxl at the Communicator defaults) is
stored alongside, so benchmarks can report regret: by construction the
chosen time is never worse than that baseline as long as the grid
contains the default slicing factor.

With a ``core.topology.Topology`` the sweep runs once per level: cells
are keyed by (level index, fabric fingerprint), priced against that
level's own fabric oracle, and the candidate set shrinks to what the
fabric can execute (the pool schedule only exists on ``cxl`` levels).

``overlap_compute`` turns the sweep overlap-aware: every candidate
(including the fixed baselines, so the regret guarantee survives) is
priced by its *exposed* time ``max(0, comm - overlappable_compute)``
instead of its in-isolation time, where the overlappable window is
either a constant (seconds) or a per-cell callable
``(primitive, msg_bytes, nranks) -> seconds`` (typically a roofline
residency of the layer compute the collective is prefetched behind).
Cells tuned this way carry ``overlap=True`` + the hidden wire time, and
``Communicator(backend='auto')`` books their bytes as overlap-hidden in
the ledger.

Primitives with fused collective+compute kernels (reduce_scatter,
all_gather - see ``kernels.fused_collectives``) additionally sweep a
``fused`` variant of every transport candidate: the fused variant's
window is widened by the roofline residency of the epilogue it absorbs
(``costmodel.fused_window``), so fusion competes in the same argmin as
backend and slicing factor.  The fixed-knob baselines stay unfused, so
regret keeps meaning "vs what a knob-free run would do".

Plans are format v6: alongside the eight collectives the sweep tunes
``p2p`` cells for the pipeline stage handoff (``Communicator.send``) -
the pool write + doorbell commit vs the direct NIC/ICI hop, priced by
``costmodel.predict_p2p_time`` (the collective oracles don't apply to
a single producer/consumer pair), with the slicing factor pipelining
the consumer read behind the producer write on the pool.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Optional, Union

from repro.core import mesh_collectives as mc
from repro.core.hw import (CXL_POOL, INFINIBAND, TPU_V5E, MiB,
                           CXLPoolConfig, InfiniBandConfig)
from repro.core.schedule import PRIMITIVES
from repro.core.topology import Topology
from repro.tuner import costmodel
from repro.tuner.plan import Choice, Plan, hardware_fingerprint


@dataclasses.dataclass(frozen=True)
class TuneGrid:
    primitives: tuple = PRIMITIVES
    sizes: tuple = tuple(m * MiB for m in (1, 4, 16, 64, 256, 1024, 4096))
    nranks: tuple = (2, 3, 4, 6, 8, 12)
    slicing_factors: tuple = (1, 2, 4, 8, 16)
    allreduce_modes: tuple = ("two_phase", "faithful")

    @property
    def cells(self) -> int:
        return len(self.primitives) * len(self.sizes) * len(self.nranks)


DEFAULT_GRID = TuneGrid()

# Grid for the lazy ``ensure_default_plan`` path and CI smoke runs:
# coarse knobs, same coverage shape, seconds not minutes.
SMOKE_GRID = TuneGrid(sizes=tuple(m * MiB for m in (1, 16, 256)),
                      nranks=(2, 3), slicing_factors=(1, 4))


def _candidates(primitive: str, grid: TuneGrid, backends=("ring", "cxl")):
    """Yield (backend, slicing_factor, allreduce_mode, fused) tuples.
    Primitives with a fused collective+compute kernel
    (``kernels.fused_collectives``: reduce_scatter epilogues, the
    all_gather-consuming matmul) get a fused variant of every
    transport candidate; the fused variant's window is widened by the
    epilogue roofline in ``_tune_cell``."""
    fusable = primitive in ("reduce_scatter", "all_gather")
    if "ring" in backends:
        yield ("ring", mc.DEFAULT_CHUNKS, "two_phase", False)
        if fusable:
            yield ("ring", mc.DEFAULT_CHUNKS, "two_phase", True)
    if "cxl" not in backends:
        return
    modes = grid.allreduce_modes if primitive == "all_reduce" \
        else ("two_phase",)
    for f, m in itertools.product(grid.slicing_factors, modes):
        yield ("cxl", f, m, False)
        if fusable:
            yield ("cxl", f, m, True)


def _p2p_candidates(grid: TuneGrid, backends=("ring", "cxl")):
    """Yield (backend, slicing_factor, allreduce_mode, fused) tuples
    for the point-to-point handoff.  Ring is one NIC/ICI transfer
    (chunking only adds per-message overhead, so factor 1); cxl sweeps
    the slicing factors - each chunk pipelines the consumer read behind
    the producer write at the cost of a doorbell ring + poll."""
    if "ring" in backends:
        yield ("ring", 1, "two_phase", False)
    if "cxl" not in backends:
        return
    for f in grid.slicing_factors:
        yield ("cxl", f, "two_phase", False)


OverlapCompute = Union[float, Callable[[str, int, int], float], None]


def _tune_cell(prim: str, n: int, size: int, window: float,
               candidates, cost_fn) -> Choice:
    """Argmin over candidates under the (possibly overlap-windowed)
    objective; the best *fixed-knob* alternative rides along so
    benchmarks can report regret."""
    best: Optional[Choice] = None
    fixed_best = math.inf
    for cand in candidates:
        backend, factor, mode = cand[:3]
        fz = bool(cand[3]) if len(cand) > 3 else False
        t_wire = cost_fn(backend, prim, n, size, factor, mode)
        # objective: exposed time under the overlap window (== t_wire
        # when no window); the window applies to every candidate, fixed
        # baselines included, so the never-slower-than-fixed guarantee
        # is preserved.  A fused candidate's window additionally folds
        # in the epilogue roofline it absorbs into the transfer; the
        # fixed baselines stay unfused so regret is measured against
        # what a knob-free run would do.
        w = costmodel.fused_window(prim, size, window) if fz else window
        t = max(0.0, t_wire - w)
        if not fz and (backend == "ring" or (factor == mc.DEFAULT_CHUNKS
                                             and mode == "two_phase")):
            fixed_best = min(fixed_best, t)
        if best is None or t < best.predicted_time:
            best = Choice(backend=backend, slicing_factor=factor,
                          allreduce_mode=mode, predicted_time=t,
                          overlap=w > 0.0,
                          hidden_time=min(t_wire, w), fused=fz)
    return dataclasses.replace(best, baseline_time=fixed_best)


def _window(overlap_compute: OverlapCompute, prim: str, size: int,
            n: int) -> float:
    if callable(overlap_compute):
        return max(0.0, overlap_compute(prim, size, n))
    return max(0.0, float(overlap_compute or 0.0))


def _level_nranks(grid: TuneGrid, topology: Topology, i: int) -> tuple:
    """The rank counts to sweep for level ``i``: the grid's, plus the
    sizes a shaped level actually runs at - its distinct group sizes
    (within-group schedules) and, when the *next* level is grouped,
    its group count (the sub-root exchange rides this level)."""
    extra = set()
    lv = topology.levels[i]
    if lv.shape is not None:
        extra |= set(lv.shape)
        if lv.grouped:
            extra.add(len(lv.shape))
    if i + 1 < len(topology.levels) and topology.levels[i + 1].grouped:
        extra.add(len(topology.levels[i + 1].shape))
    return tuple(sorted(set(grid.nranks) | {n for n in extra if n >= 2}))


def generate_plan(grid: TuneGrid = DEFAULT_GRID, *,
                  pool: CXLPoolConfig = CXL_POOL,
                  ib: InfiniBandConfig = INFINIBAND,
                  topology: Optional[Topology] = None,
                  overlap_compute: OverlapCompute = None,
                  progress: Optional[Callable[[str], None]] = None) -> Plan:
    """Sweep the grid into a Plan.

    Without a topology every cell is priced against the single global
    (pool, ib) pair - the flat two-backend regime.  With a topology the
    sweep runs once per level: each cell is keyed by
    (level index, fabric fingerprint) and priced against that level's
    own fabric config (``costmodel.predict_level_time``), with the
    candidate set restricted to the backends the fabric can execute.
    Shaped levels extend their swept rank counts with the sizes they
    actually run at (distinct group sizes; the group count lands on
    the parent level, which carries the sub-root exchange), so ragged
    lookups resolve exactly instead of falling to the nearest tuned
    nranks.  The topology is embedded in the plan metadata and its
    fingerprint becomes the plan fingerprint, so ``tune -> train``
    round-trips through one JSON file.
    """
    overlap_meta = ("per-cell" if callable(overlap_compute)
                    else float(overlap_compute or 0.0))
    if topology is None:
        plan = Plan(fingerprint=hardware_fingerprint(pool, ib),
                    meta={"grid": dataclasses.asdict(grid),
                          "overlap_compute_s": overlap_meta})

        def cost(backend, prim, n, size, factor, mode):
            return costmodel.predict_time(
                backend, prim, n, size, slicing_factor=factor,
                allreduce_mode=mode, pool=pool, ib=ib)

        for prim in grid.primitives:
            for n in grid.nranks:
                for size in grid.sizes:
                    w = _window(overlap_compute, prim, size, n)
                    plan.add(prim, size, n, _tune_cell(
                        prim, n, size, w, _candidates(prim, grid), cost))
                if progress:
                    progress(f"tuned {prim} nranks={n}")

        def p2p_cost(backend, prim, n, size, factor, mode):
            return costmodel.predict_p2p_time(
                backend, size, slicing_factor=factor, pool=pool, ib=ib)

        for n in grid.nranks:
            for size in grid.sizes:
                w = _window(overlap_compute, "p2p", size, n)
                plan.add("p2p", size, n, _tune_cell(
                    "p2p", n, size, w, _p2p_candidates(grid), p2p_cost))
            if progress:
                progress(f"tuned p2p nranks={n}")
        return plan

    plan = Plan(fingerprint=topology.fingerprint(),
                meta={"grid": dataclasses.asdict(grid),
                      "overlap_compute_s": overlap_meta,
                      "topology": topology.to_json()})
    for i, level in enumerate(topology.levels):
        lkey = topology.level_key(level.axis)
        level_nranks = _level_nranks(grid, topology, i)

        def cost(backend, prim, n, size, factor, mode, _lv=level):
            return costmodel.predict_level_time(
                _lv, prim, n, size, backend=backend,
                slicing_factor=factor, allreduce_mode=mode)

        for prim in grid.primitives:
            for n in level_nranks:
                for size in grid.sizes:
                    w = _window(overlap_compute, prim, size, n)
                    plan.add(prim, size, n, _tune_cell(
                        prim, n, size, w,
                        _candidates(prim, grid, level.backends()), cost),
                        level=lkey)
                if progress:
                    progress(f"tuned {prim} nranks={n} "
                             f"level={level.axis}/{level.fabric}")

        def p2p_cost(backend, prim, n, size, factor, mode, _lv=level):
            return costmodel.predict_level_p2p_time(
                _lv, size, backend=backend, slicing_factor=factor)

        for n in level_nranks:
            for size in grid.sizes:
                w = _window(overlap_compute, "p2p", size, n)
                plan.add("p2p", size, n, _tune_cell(
                    "p2p", n, size, w,
                    _p2p_candidates(grid, level.backends()), p2p_cost),
                    level=lkey)
            if progress:
                progress(f"tuned p2p nranks={n} "
                         f"level={level.axis}/{level.fabric}")
    return plan


def overlap_windows_from_dryrun(records: list, *,
                                peak_flops: float = TPU_V5E.peak_flops_bf16,
                                hbm_bw: float = TPU_V5E.hbm_bw
                                ) -> Callable[[str, int, int], float]:
    """Derive per-cell overlap windows from dry-run roofline records
    (ROADMAP overlap follow-up: replace the constant window).

    Each dry-run record carries the compiled step's FLOPs / HBM bytes
    (``cost_analysis``) and the trace-time ledger (per-primitive wire
    bytes and true launch counts).  The roofline residency of the step
    is apportioned to primitives by their wire-byte share and divided
    by that primitive's launch count: the result is the average compute
    window one launch of that primitive can hide behind.  Returns a
    ``(primitive, msg_bytes, nranks) -> seconds`` callable for
    ``generate_plan(overlap_compute=...)``.
    """
    tot_window: dict = {}
    tot_n: dict = {}
    for rec in records:
        if rec.get("status") != "ok":
            continue
        cost = rec.get("cost") or {}
        led = rec.get("ledger") or {}
        compute = costmodel.roofline_compute_time(
            float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            peak_flops=peak_flops, hbm_bw=hbm_bw)
        wire = led.get("wire_bytes") or {}
        calls = led.get("collective_calls") or {}
        total_bytes = sum(wire.values())
        if compute <= 0.0 or total_bytes <= 0.0:
            continue
        for prim, b in wire.items():
            n_calls = max(1.0, float(calls.get(prim, 1.0)))
            w = compute * (b / total_bytes) / n_calls
            tot_window[prim] = tot_window.get(prim, 0.0) + w
            tot_n[prim] = tot_n.get(prim, 0) + 1
    windows = {p: tot_window[p] / tot_n[p] for p in tot_window}

    def window(primitive: str, msg_bytes: int, nranks: int) -> float:
        return windows.get(primitive, 0.0)

    window.per_primitive = windows  # introspectable for reports/tests
    return window
