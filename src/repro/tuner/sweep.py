"""Offline tuning sweep: grid -> Plan.

For every (primitive, msg_bytes, nranks) cell the sweep costs a fixed
``ring`` candidate plus every (slicing_factor, allreduce_mode) ``cxl``
candidate, and records the argmin as the plan entry.  The best
*fixed-knob* alternative (ring, or cxl at the Communicator defaults) is
stored alongside, so benchmarks can report regret: by construction the
chosen time is never worse than that baseline as long as the grid
contains the default slicing factor.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Callable, Optional

from repro.core import mesh_collectives as mc
from repro.core.hw import (CXL_POOL, INFINIBAND, MiB, CXLPoolConfig,
                           InfiniBandConfig)
from repro.core.schedule import PRIMITIVES
from repro.tuner import costmodel
from repro.tuner.plan import Choice, Plan, hardware_fingerprint


@dataclasses.dataclass(frozen=True)
class TuneGrid:
    primitives: tuple = PRIMITIVES
    sizes: tuple = tuple(m * MiB for m in (1, 4, 16, 64, 256, 1024, 4096))
    nranks: tuple = (2, 3, 4, 6, 8, 12)
    slicing_factors: tuple = (1, 2, 4, 8, 16)
    allreduce_modes: tuple = ("two_phase", "faithful")

    @property
    def cells(self) -> int:
        return len(self.primitives) * len(self.sizes) * len(self.nranks)


DEFAULT_GRID = TuneGrid()

# Grid for the lazy ``ensure_default_plan`` path and CI smoke runs:
# coarse knobs, same coverage shape, seconds not minutes.
SMOKE_GRID = TuneGrid(sizes=tuple(m * MiB for m in (1, 16, 256)),
                      nranks=(2, 3), slicing_factors=(1, 4))


def _candidates(primitive: str, grid: TuneGrid):
    yield ("ring", mc.DEFAULT_CHUNKS, "two_phase")
    modes = grid.allreduce_modes if primitive == "all_reduce" \
        else ("two_phase",)
    for f, m in itertools.product(grid.slicing_factors, modes):
        yield ("cxl", f, m)


def generate_plan(grid: TuneGrid = DEFAULT_GRID, *,
                  pool: CXLPoolConfig = CXL_POOL,
                  ib: InfiniBandConfig = INFINIBAND,
                  progress: Optional[Callable[[str], None]] = None) -> Plan:
    plan = Plan(fingerprint=hardware_fingerprint(pool, ib),
                meta={"grid": dataclasses.asdict(grid)})
    for prim in grid.primitives:
        for n in grid.nranks:
            for size in grid.sizes:
                best: Optional[Choice] = None
                fixed_best = math.inf
                for backend, factor, mode in _candidates(prim, grid):
                    t = costmodel.predict_time(
                        backend, prim, n, size, slicing_factor=factor,
                        allreduce_mode=mode, pool=pool, ib=ib)
                    if backend == "ring" or (
                            factor == mc.DEFAULT_CHUNKS
                            and mode == "two_phase"):
                        fixed_best = min(fixed_best, t)
                    if best is None or t < best.predicted_time:
                        best = Choice(backend=backend,
                                      slicing_factor=factor,
                                      allreduce_mode=mode,
                                      predicted_time=t)
                best = dataclasses.replace(best, baseline_time=fixed_best)
                plan.add(prim, size, n, best)
            if progress:
                progress(f"tuned {prim} nranks={n}")
    return plan
