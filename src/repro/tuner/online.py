"""Online re-tuning: measured-cost feedback into the plan.

The offline plans (``tuner.sweep``) are priced entirely by oracles -
the pool simulator, the IB alpha-beta model, the ICI ring model - so a
miscalibrated oracle silently drives ``backend='auto'`` to the wrong
choice forever.  This module closes the loop:

1. **Observe**: measured per-collective wall times arrive either as
   ledger-tagged timing samples (``core.ledger.record_timing`` /
   ``ledger.timed`` around an eagerly dispatched collective) or as
   measured *step* times apportioned over the step's trace-time
   ``auto_choices`` audit by predicted-time share
   (``OnlineTuner.observe_step`` - the ROADMAP's "feed measured step
   times back into the plan"), or - the preferred path - as
   per-collective profiler/emulator samples parsed by ``repro.obs.
   profile`` and booked through the same ledger capture.  Samples
   aggregate per plan cell key ``(primitive, size bucket,
   nranks[, level])`` *and* per candidate ``(backend, slicing_factor,
   allreduce_mode)`` as an exponentially-weighted moving average,
   weighted by the sample's true per-step trip count (``calls``, the
   ledger's ambient ``scale()`` stamp).  A sample whose knobs are
   unknown (``None``) aggregates under an explicit ``?`` pseudo-
   candidate - never into a real candidate's mean - except for
   ``ring``, whose single candidate ignores the knobs by construction.

2. **Refresh**: ``OnlineTuner.refresh`` re-resolves every cell of the
   base plan: each candidate is priced by its measured EWMA once the
   cell has ``min_samples`` samples for it, and by the offline oracle
   otherwise.  The argmin becomes the new cell choice, with the
   measured feedback persisted in the plan (format v4:
   ``measured_us``/``sample_count``/``ewma_alpha``), so a saved
   refreshed plan warm-starts the next run's tuner.

   Oracle-priced candidates are additionally corrected by learned
   **calibration scales**: every measurement also folds the ratio
   measured/oracle into a sample-weighted mean keyed ``(backend,
   level, primitive)``, and unmeasured candidates are priced
   ``oracle * scale`` once the scale has ``min_samples`` of support.  Measurements thereby correct
   the oracle *everywhere* that backend runs that primitive on that
   level - not just at measured cells.  (The primitive stays in the
   key so one pathological broadcast measurement cannot reprice
   all_reduce cells; the per-(backend, level) aggregate is still
   persisted/reported for fabric-drift detection, ``obs.health``.)
   Scales ride in plan ``meta["calibration"]`` and warm-start the next
   run's tuner alongside the measured cells.

3. **Hot-swap**: ``refresh_and_activate`` publishes the refreshed plan
   through the epoch-versioned active-plan registry
   (``tuner.runtime.set_active_plan``).  ``Communicator`` resolution
   happens per call against the registry, so the next trace of the
   step picks the new plan up; launchers re-trace at retune boundaries
   only when ``choices_changed`` says the resolution actually moved.

Convergence mechanics: a 4x-optimistic pool oracle makes ``auto`` pick
``cxl`` where ``ring`` truly wins.  The wrongly-chosen backend is what
gets executed, so it is what gets *measured*; once its measured EWMA
overrides the oracle, the argmin compares (bad) measured cxl against
(oracle) ring and flips.  The newly chosen backend then gets measured
in turn and either confirms or flips back - the same
explore-by-exploitation loop Meta's 100k+-GPU collective tuning runs
with continuously refreshed cost tables (``benchmarks/retune.py``
demonstrates bounded-step convergence).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

from repro.core import mesh_collectives as mc
from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)
from repro.tuner import costmodel
from repro.tuner.plan import Choice, Plan, size_bucket
from repro.tuner.sweep import (DEFAULT_GRID, TuneGrid, _candidates,
                               _p2p_candidates)

DEFAULT_ALPHA = 0.3         # EWMA smoothing factor
DEFAULT_MIN_SAMPLES = 3     # samples before measured overrides oracle
DEFAULT_RETUNE_INTERVAL = 10
UNKNOWN = "?"               # pseudo-knob for samples with unknown knobs
_LKEY_RE = re.compile(r"\d+:[0-9a-f]+")   # "<idx>:<fabric fp>"


def cell_key(primitive: str, msg_bytes: int, nranks: int,
             level: Optional[str] = None) -> tuple:
    """The plan-cell identity a measurement aggregates into - exactly
    the key ``Plan.add`` builds."""
    key = (primitive, size_bucket(max(1, int(msg_bytes))), int(nranks))
    return key + (level,) if level is not None else key


@dataclasses.dataclass
class CellStats:
    """EWMA of measured wall time for one (cell, candidate).

    ``weight`` is the sample's true per-step launch count (the
    ledger's ``calls`` stamp): a sample that stands for ``w`` launches
    of a scanned region moves the EWMA as if observed ``w`` times
    (``alpha_eff = 1 - (1-alpha)^w``) and advances the sample count by
    ``w``."""

    ewma_seconds: float = 0.0
    samples: float = 0.0

    def update(self, seconds: float, alpha: float,
               weight: float = 1.0) -> None:
        w = max(0.0, float(weight))
        if w == 0.0:
            return
        if self.samples == 0:
            self.ewma_seconds = seconds
        else:
            a = 1.0 - (1.0 - alpha) ** w
            self.ewma_seconds = (a * seconds
                                 + (1.0 - a) * self.ewma_seconds)
        self.samples += w


@dataclasses.dataclass
class CalStats:
    """Sample-weighted mean of the measured/oracle time ratio for one
    (backend, level, primitive) - the learned calibration scale that
    corrects oracle-priced candidates everywhere, not just at measured
    cells.  A *mean*, not an EWMA, deliberately: the ratio varies
    across the cells that feed one key (the oracle's error is not
    uniform in size/nranks), and an EWMA would slosh toward whichever
    cell folded last, repricing unmeasured candidates differently at
    every retune boundary and reopening settled cells.  The mean is
    the stationary estimate; *drift* (real hardware change) is the
    health monitor's job (``obs.health``), and per-cell truth always
    wins anyway once the cell's own measured EWMA overrides."""

    scale: float = 1.0
    samples: float = 0.0

    def update(self, ratio: float, weight: float = 1.0) -> None:
        w = max(0.0, float(weight))
        if w == 0.0 or ratio <= 0.0:
            return
        tot = self.samples + w
        self.scale = (self.scale * self.samples + float(ratio) * w) / tot
        self.samples = tot


def _grid_from_meta(meta: dict) -> TuneGrid:
    g = meta.get("grid")
    if not g:
        return DEFAULT_GRID
    return TuneGrid(
        primitives=tuple(g.get("primitives", DEFAULT_GRID.primitives)),
        sizes=tuple(g.get("sizes", DEFAULT_GRID.sizes)),
        nranks=tuple(g.get("nranks", DEFAULT_GRID.nranks)),
        slicing_factors=tuple(g.get("slicing_factors",
                                    DEFAULT_GRID.slicing_factors)),
        allreduce_modes=tuple(g.get("allreduce_modes",
                                    DEFAULT_GRID.allreduce_modes)))


class OnlineTuner:
    """Accumulates measured collective times and folds them back into
    a plan.  One instance per training/serving run; the base plan's
    persisted ``measured_us`` cells warm-start the EWMAs, so a
    ``tune -> train --plan-out -> train`` chain keeps learning.

    ``pool``/``ib`` are the *oracle* configs unmeasured candidates are
    priced with at refresh time - deliberately the same (possibly
    miscalibrated) oracle the base plan was tuned with: measurements
    are the only source of truth the online layer adds.

    Two recovery knobs, both off by default (a converged tuner with
    default knobs refreshes to the identical plan, bit for bit):

    * ``decay`` relaxes every measured EWMA toward the calibration-
      corrected oracle at each refresh (and shrinks its effective
      sample count), so a fabric that measured slow *while degraded*
      does not carry that verdict forever - stale evidence fades and
      the oracle regains its vote.
    * ``explore_eps`` is epsilon-greedy exploration at refresh: with
      probability eps per measured cell, the refreshed plan runs a
      non-winning candidate instead of the argmin, so the recovered
      fabric gets re-measured at all (pure exploitation never
      re-executes a loser, hence never notices it recovered).
    """

    def __init__(self, plan: Plan, *, alpha: float = DEFAULT_ALPHA,
                 min_samples: int = DEFAULT_MIN_SAMPLES,
                 retune_interval: int = DEFAULT_RETUNE_INTERVAL,
                 calibration_min_samples: Optional[int] = None,
                 decay: float = 0.0, explore_eps: float = 0.0,
                 explore_seed: int = 0,
                 pool: CXLPoolConfig = CXL_POOL,
                 ib: InfiniBandConfig = INFINIBAND):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"ewma alpha must be in (0, 1], got {alpha}")
        if retune_interval < 1:
            raise ValueError("retune_interval must be >= 1")
        if not 0.0 <= decay < 1.0:
            raise ValueError(f"decay must be in [0, 1), got {decay}")
        if not 0.0 <= explore_eps < 1.0:
            raise ValueError(
                f"explore_eps must be in [0, 1), got {explore_eps}")
        self.plan = plan
        self.alpha = float(alpha)
        self.min_samples = max(1, int(min_samples))
        # Generalizing a correction across every cell of a (backend,
        # level, primitive) takes more evidence than overriding one
        # measured cell, so the calibration floor never drops below 2.
        self.cal_min_samples = max(2, self.min_samples) \
            if calibration_min_samples is None \
            else max(1, int(calibration_min_samples))
        self.retune_interval = int(retune_interval)
        self.decay = float(decay)
        self.explore_eps = float(explore_eps)
        self._explore_rng = np.random.default_rng(explore_seed)
        self.explored: list = []    # (refresh_count, key, candidate)
        self.pool = pool
        self.ib = ib
        self.grid = _grid_from_meta(plan.meta)
        # The plan's embedded topology, else the process-wide active
        # one: a *flat* plan driven under `--topology` still audits
        # level tags by axis name, and feedback keyed by an unmappable
        # axis name would land in cells runtime lookup never queries.
        self.topology = plan.topology()
        if self.topology is None:
            from repro.core.topology import get_active_topology
            self.topology = get_active_topology()
        # level key "idx:fp" -> Level, and axis name -> level key, so
        # observations may tag either spelling
        self._levels = {}
        self._axis_lkey = {}
        if self.topology is not None:
            for lv in self.topology.levels:
                lkey = self.topology.level_key(lv.axis)
                self._levels[lkey] = lv
                self._axis_lkey[lv.axis] = lkey
        # Overlap objective of the base plan: a constant window is
        # reconstructable from the meta and re-applied at refresh so
        # re-resolution competes under the same exposed-time objective
        # the sweep used; per-cell (dry-run-derived) windows are not
        # serialized, so unmeasured cells then keep their offline
        # choice instead of being re-argmin'd under the wrong
        # objective.
        w = plan.meta.get("overlap_compute_s", 0.0)
        self.overlap_window = float(w) if not isinstance(w, str) else 0.0
        self.window_unknown = isinstance(w, str)     # "per-cell"
        # (cell key, (backend, factor, mode)) -> CellStats
        self.stats: dict = {}
        # (backend, level key or None, primitive) -> CalStats
        self.calibration: dict = {}
        self.refresh_count = 0
        for key, ch in plan.entries.items():
            if ch.sample_count > 0 and ch.measured_us > 0.0:
                cand = (ch.backend, ch.slicing_factor, ch.allreduce_mode)
                self.stats[(key, cand)] = CellStats(
                    ewma_seconds=ch.measured_us * 1e-6,
                    samples=float(ch.sample_count))
        # persisted calibration scales warm-start the ratio EWMAs, so a
        # tune -> train --plan-out -> train chain keeps its corrected
        # oracle across processes
        for e in (plan.meta.get("calibration") or {}).get("scales", []):
            self.calibration[(e["backend"], e.get("level"),
                              e["primitive"])] = CalStats(
                scale=float(e["scale"]), samples=float(e["samples"]))

    # -- observation ------------------------------------------------------

    def _lkey(self, level: Optional[str]) -> Optional[str]:
        if level is None:
            return None
        if level in self._axis_lkey:       # topology axis name
            return self._axis_lkey[level]
        if level in self._levels:          # already a level key
            return level
        if _LKEY_RE.fullmatch(level):
            # a raw "<idx>:<fabric fp>" key from a persisted record
            # whose topology this tuner does not know: keep it verbatim
            return level
        # an axis name with no topology in scope: cells keyed by it
        # would be unreachable at lookup time - aggregate level-
        # agnostically instead of silently dropping the sample
        return None

    @staticmethod
    def _cand(backend: str, slicing_factor, allreduce_mode) -> tuple:
        """Normalize the executed candidate.  ``ring`` has exactly one
        candidate (NCCL picks its own chunking), so unknown knobs are
        unambiguous there; for ``cxl`` an unknown knob keys an explicit
        ``?`` pseudo-candidate that never matches a real one - it can
        not contaminate a tuned cell's mean."""
        if backend == "ring":
            return (backend, mc.DEFAULT_CHUNKS, "two_phase")
        if slicing_factor is None or allreduce_mode is None:
            return (backend, UNKNOWN, UNKNOWN)
        return (backend, int(slicing_factor), allreduce_mode)

    def observe(self, primitive: str, msg_bytes: int, nranks: int,
                backend: str, seconds: float, *,
                slicing_factor: "int | None" = 4,
                allreduce_mode: "str | None" = "two_phase",
                level: Optional[str] = None,
                calls: float = 1.0) -> None:
        """Fold one measured wall-time sample into the per-cell EWMA
        (weighted by ``calls``, its true per-step trip count) and the
        per-(backend, level, primitive) calibration-ratio EWMA.
        ``level`` accepts either the topology axis name (what the
        ledger tags) or the plan's ``"<idx>:<fabric fp>"`` level key."""
        if nranks <= 1 or seconds < 0.0:
            return
        lkey = self._lkey(level)
        key = cell_key(primitive, msg_bytes, nranks, lkey)
        cand = self._cand(backend, slicing_factor, allreduce_mode)
        st = self.stats.setdefault((key, cand), CellStats())
        st.update(float(seconds), self.alpha, weight=calls)
        if UNKNOWN in cand:
            return        # cannot price an unknown candidate's oracle
        oracle = self._oracle_at(primitive, int(msg_bytes), int(nranks),
                                 lkey, *cand)
        if oracle > 1e-12:
            cs = self.calibration.setdefault(
                (backend, lkey, primitive), CalStats())
            cs.update(float(seconds) / oracle, weight=calls)

    def observe_timings(self, timings: list) -> int:
        """Consume ledger timing samples (``snapshot()["timings"]`` or
        a persisted copy).  Returns the number of samples folded in."""
        n = 0
        for t in timings:
            self.observe(t["primitive"], t["msg_bytes"], t["nranks"],
                         t["backend"], t["seconds"],
                         slicing_factor=t.get("slicing_factor"),
                         allreduce_mode=t.get("allreduce_mode"),
                         level=t.get("level"),
                         calls=t.get("calls", 1.0))
            n += 1
        return n

    def observe_step(self, step_seconds: float, choices: list) -> int:
        """Apportion one measured step wall time over the step's
        trace-time ``auto_choices`` audit by predicted-time share.

        Each audited choice carries the oracle's ``predicted_time`` and
        its true per-step launch count ``calls``; the step's measured
        time is split across cells proportionally to
        ``predicted_time * calls`` and divided back by ``calls`` to
        yield a per-launch sample (assuming a communication-dominated
        step).

        Scope of this signal: one scalar per step can only rescale the
        oracle's per-cell predictions by a common factor - it corrects
        *overall* drift (e.g. every collective running 2x slower than
        modeled, from fabric contention) and detects that the plan's
        predictions no longer match reality, but it cannot re-rank
        candidates *within* a cell, because each cell's apportioned
        sample inherits the oracle's own relative weights.  Correcting
        a non-uniformly mis-calibrated oracle (the pool model wrong,
        the IB model right) requires per-collective samples:
        ``ledger.record_timing`` / ``ledger.timed`` around eagerly
        dispatched collectives, or folded offline from profiler traces
        via ``tune --measurements`` - the path ``benchmarks/retune.py``
        demonstrates converging."""
        total = sum(max(0.0, c.get("predicted_time", 0.0))
                    * max(1.0, c.get("calls", 1.0)) for c in choices)
        if step_seconds <= 0.0 or total <= 0.0:
            return 0
        n = 0
        for c in choices:
            pred = max(0.0, c.get("predicted_time", 0.0))
            calls = max(1.0, c.get("calls", 1.0))
            if pred <= 0.0:
                continue
            per_launch = step_seconds * (pred * calls / total) / calls
            self.observe(c["primitive"], c["msg_bytes"], c["nranks"],
                         c["backend"], per_launch,
                         slicing_factor=c.get("slicing_factor", 4),
                         allreduce_mode=c.get("allreduce_mode",
                                              "two_phase"),
                         level=c.get("level"), calls=calls)
            n += 1
        return n

    # -- repricing --------------------------------------------------------

    def _oracle_at(self, primitive: str, msg_bytes: int, nranks: int,
                   lkey: Optional[str], backend: str, factor: int,
                   mode: str) -> float:
        """Oracle time at the *actual* message size (not the bucket
        floor), for calibration ratios."""
        if primitive == "p2p":
            # point-to-point cells price through the dedicated p2p
            # oracles (the collective models key EFFICIENCY/ALPHA by
            # primitive and don't know the stage handoff)
            if lkey is not None and lkey in self._levels:
                return costmodel.predict_level_p2p_time(
                    self._levels[lkey], msg_bytes, backend=backend,
                    slicing_factor=factor)
            return costmodel.predict_p2p_time(
                backend, msg_bytes, slicing_factor=factor,
                pool=self.pool, ib=self.ib)
        if lkey is not None and lkey in self._levels:
            return costmodel.predict_level_time(
                self._levels[lkey], primitive, nranks, msg_bytes,
                backend=backend, slicing_factor=factor,
                allreduce_mode=mode)
        return costmodel.predict_time(
            backend, primitive, nranks, msg_bytes,
            slicing_factor=factor, allreduce_mode=mode,
            pool=self.pool, ib=self.ib)

    def _oracle_time(self, key: tuple, backend: str, factor: int,
                     mode: str) -> float:
        lkey = key[3] if len(key) == 4 else None
        return self._oracle_at(key[0], 1 << key[1], key[2], lkey,
                               backend, factor, mode)

    def cal_scale(self, backend: str, lkey: Optional[str],
                  primitive: str) -> float:
        """The learned measured/oracle correction applied to
        oracle-priced candidates (1.0 until ``cal_min_samples`` ratio
        samples landed for the (backend, level, primitive))."""
        cs = self.calibration.get((backend, lkey, primitive))
        if cs is not None and cs.samples >= self.cal_min_samples:
            return cs.scale
        return 1.0

    def cost(self, key: tuple, backend: str, factor: int,
             mode: str) -> tuple:
        """(cost seconds, stats or None) of one candidate for one cell:
        the measured EWMA once ``min_samples`` samples landed for that
        exact candidate, the calibration-corrected offline oracle
        otherwise - windowed by the base plan's constant overlap
        objective, so oracle-priced candidates compete on the same
        exposed-time terms the sweep tuned with (measured wall times
        are already exposure)."""
        st = self.stats.get((key, (backend, factor, mode)))
        if st is not None and st.samples >= self.min_samples:
            return st.ewma_seconds, st
        lkey = key[3] if len(key) == 4 else None
        t = self._oracle_time(key, backend, factor, mode) \
            * self.cal_scale(backend, lkey, key[0])
        return max(0.0, t - self.overlap_window), st

    def _decay_stats(self) -> None:
        """Relax every measured EWMA toward the calibration-corrected
        oracle and shrink its effective sample count by ``decay``.

        Run once per refresh.  Evidence gathered under a fault ages
        out two ways: the EWMA value itself drifts back to what the
        (calibrated) oracle says the candidate should cost, and the
        shrinking sample count eventually drops below ``min_samples``,
        at which point pricing falls back to the oracle entirely.
        Fresh measurements re-anchor both - a candidate that is
        *still* slow keeps getting re-measured slow by exploration, so
        only stale verdicts fade."""
        if self.decay <= 0.0:
            return
        for (key, cand), st in self.stats.items():
            if st.samples <= 0.0:
                continue
            if UNKNOWN not in cand:
                lkey = key[3] if len(key) == 4 else None
                target = self._oracle_time(key, *cand) \
                    * self.cal_scale(cand[0], lkey, key[0])
                st.ewma_seconds += self.decay * (target
                                                 - st.ewma_seconds)
            st.samples *= (1.0 - self.decay)
        # The calibration ratios fade with the per-cell evidence: a
        # scale learned under a since-healed fault would otherwise
        # reprice the oracle with the stale slowdown forever (and the
        # EWMA decay above would converge to it rather than escape
        # it).  Scale relaxes toward 1.0, support shrinks until it
        # drops below cal_min_samples and the raw oracle votes again.
        for cs in self.calibration.values():
            if cs.samples <= 0.0:
                continue
            cs.scale += self.decay * (1.0 - cs.scale)
            cs.samples *= (1.0 - self.decay)

    def _measured_keys(self) -> set:
        """Cell keys with at least one *real* candidate past
        min_samples (unknown-knob pseudo-candidates don't count: they
        can never price a refresh)."""
        return {k for (k, c), st in self.stats.items()
                if st.samples >= self.min_samples and UNKNOWN not in c}

    def refresh(self) -> Plan:
        """Re-resolve every cell of the base plan - plus every cell the
        workload was actually *measured* at - under measured-over-
        oracle costing; returns a new format-v4 plan (the base plan is
        untouched).

        Growing cells at the observed size buckets matters: the tuned
        grid rarely matches the workload's message sizes exactly, and
        runtime lookup falls back to the nearest tuned bucket.  Once a
        measured cell exists at the workload's own bucket, lookup
        resolves it exactly and the measured cost - not a neighboring
        bucket's oracle guess - drives the choice."""
        self.refresh_count += 1
        self._decay_stats()
        explored_before = len(self.explored)
        meta = dict(self.plan.meta)
        measured_cells = sum(
            1 for (key, cand), st in self.stats.items()
            if st.samples >= self.min_samples)
        meta["online"] = {"ewma_alpha": self.alpha,
                          "min_samples": self.min_samples,
                          "refresh_count": self.refresh_count,
                          "measured_candidates": measured_cells}
        if self.decay > 0.0 or self.explore_eps > 0.0:
            meta["online"]["decay"] = self.decay
            meta["online"]["explore_eps"] = self.explore_eps
        if self.calibration:
            meta["calibration"] = self.calibration_export()
        out = Plan(fingerprint=self.plan.fingerprint, meta=meta)
        measured_keys = self._measured_keys()
        keys = set(self.plan.entries)
        keys.update(key for key, _cand in self.stats)
        for key in sorted(keys, key=lambda k: (k[0], k[1], k[2],
                                               k[3] if len(k) == 4
                                               else "")):
            lkey = key[3] if len(key) == 4 else None
            base_ch = self.plan.entries.get(key)
            if base_ch is None:
                # measured-only cell: inherit baseline/overlap context
                # from the nearest tuned cell (what lookup served the
                # workload from before this cell existed)
                base_ch = self.plan.lookup(key[0], 1 << key[1], key[2],
                                           level=lkey)
            if base_ch is None:      # untuned primitive: ring context
                base_ch = Choice(backend="ring")
            if self.window_unknown and key not in measured_keys:
                # tuned under per-cell overlap windows this tuner
                # cannot reconstruct: without measurements there is no
                # basis to overturn the offline choice
                out.entries[key] = base_ch
                continue
            level = self._levels.get(lkey) if lkey is not None else None
            backends = level.backends() if level is not None \
                else ("ring", "cxl")
            best = None
            best_cost = None
            best_st = None
            priced = {}
            # p2p cells compete over the handoff candidate set (ring
            # is a single hop: factor 1, no fused variants), matching
            # what the offline sweep resolved them against
            cands = _p2p_candidates(self.grid, backends) \
                if key[0] == "p2p" else \
                _candidates(key[0], self.grid, backends)
            for cand in cands:
                if len(cand) > 3 and cand[3]:
                    # fused variants have no measured channel (the
                    # ledger times the collective, not the fused
                    # kernel), so the online refresh compares the
                    # transport candidates only and carries the
                    # offline fusion verdict through unchanged below
                    continue
                backend, factor, mode = cand[:3]
                t, st = self.cost(key, backend, factor, mode)
                priced[(backend, factor, mode)] = (t, st)
                if best_cost is None or t < best_cost:
                    best = (backend, factor, mode)
                    best_cost = t
                    best_st = st
            # epsilon-greedy: a measured cell occasionally runs a
            # non-winning candidate so losers get re-measured (the
            # only way the tuner can notice a fabric recovered)
            if (self.explore_eps > 0.0 and key in measured_keys
                    and len(priced) > 1
                    and self._explore_rng.random() < self.explore_eps):
                others = sorted(c for c in priced if c != best)
                best = others[int(self._explore_rng.integers(
                    len(others)))]
                best_cost, best_st = priced[best]
                self.explored.append((self.refresh_count, key, best))
            # unchanged choices keep their overlap pricing; a flipped
            # cell re-derives it from the constant window (zero when
            # the base plan was tuned in isolation)
            same = best == (base_ch.backend, base_ch.slicing_factor,
                            base_ch.allreduce_mode)
            wire = self._oracle_time(key, *best) \
                * self.cal_scale(best[0], lkey, key[0])
            # a surviving fused verdict keeps the sweep's objective: its
            # window folds in the epilogue roofline, so repricing under
            # the bare constant window would drift predicted_time on
            # every refresh even when nothing changed
            win = costmodel.fused_window(key[0], 1 << key[1],
                                         self.overlap_window) \
                if (same and base_ch.fused) else self.overlap_window
            out.entries[key] = Choice(
                backend=best[0], slicing_factor=best[1],
                allreduce_mode=best[2],
                predicted_time=max(0.0, wire - win),
                baseline_time=base_ch.baseline_time,
                overlap=(base_ch.overlap if same
                         else self.overlap_window > 0.0),
                hidden_time=(base_ch.hidden_time if same
                             else min(wire, self.overlap_window)),
                measured_us=(best_st.ewma_seconds * 1e6
                             if best_st is not None else 0.0),
                sample_count=(int(round(best_st.samples))
                              if best_st is not None else 0),
                ewma_alpha=self.alpha if best_st is not None else 0.0,
                # the offline fusion verdict survives a refresh as long
                # as the transport choice does; a flipped cell reverts
                # to unfused until the next offline sweep re-prices it
                fused=(base_ch.fused if same else False))
        if len(self.explored) > explored_before:
            meta["online"]["explored_cells"] = (len(self.explored)
                                                - explored_before)
        return out

    # -- calibration + regret readouts ------------------------------------

    def calibration_export(self) -> dict:
        """The learned calibration table, as persisted in plan
        ``meta["calibration"]``: the full per-(backend, level,
        primitive) ``scales`` (what pricing uses and what warm-starts
        the next run), plus the per-(backend, level) aggregate
        ``levels`` - the fabric-level drift signal ``obs.health``
        consumes (sample-weighted mean of the primitive scales)."""
        scales = [{"backend": b, "level": lk, "primitive": p,
                   "scale": cs.scale, "samples": cs.samples}
                  for (b, lk, p), cs in sorted(
                      self.calibration.items(),
                      key=lambda kv: (kv[0][0], kv[0][1] or "",
                                      kv[0][2]))]
        agg: dict = {}
        for (b, lk, _p), cs in self.calibration.items():
            tot = agg.setdefault((b, lk), [0.0, 0.0])
            tot[0] += cs.scale * cs.samples
            tot[1] += cs.samples
        levels = [{"backend": b, "level": lk,
                   "scale": (s / n if n > 0.0 else 1.0), "samples": n}
                  for (b, lk), (s, n) in sorted(
                      agg.items(), key=lambda kv: (kv[0][0],
                                                   kv[0][1] or ""))]
        return {"scales": scales, "levels": levels}

    def measured_regret(self) -> float:
        """Per-launch regret (seconds) the measurements can prove: for
        every cell whose *current* choice is measured, the gap between
        its EWMA and the best measured candidate's EWMA.  Zero when
        every measured cell already runs its measured-fastest
        candidate - the plan-cell regret gauge ``obs.metrics``
        exports."""
        best: dict = {}
        for (key, cand), st in self.stats.items():
            if UNKNOWN in cand or st.samples < self.min_samples:
                continue
            cur = best.get(key)
            if cur is None or st.ewma_seconds < cur:
                best[key] = st.ewma_seconds
        regret = 0.0
        for key, best_s in best.items():
            ch = self.plan.entries.get(key)
            if ch is None:
                continue
            st = self.stats.get(
                (key, (ch.backend, ch.slicing_factor,
                       ch.allreduce_mode)))
            if st is not None and st.samples >= self.min_samples:
                regret += max(0.0, st.ewma_seconds - best_s)
        return regret

    # -- hot-swap ---------------------------------------------------------

    def refresh_and_activate(self) -> Plan:
        """Refresh + publish through the epoch-versioned registry.  The
        refreshed plan also becomes this tuner's base, so subsequent
        refreshes re-resolve from the latest measured state."""
        from repro.tuner import runtime
        plan = self.refresh()
        self.plan = plan
        runtime.set_active_plan(plan)
        return plan

    def maybe_retune(self, step_index: int) -> Optional[Plan]:
        """Hot-swap hook for step loops: refresh + activate every
        ``retune_interval`` steps (at the *end* of the interval's last
        step).  Returns the refreshed plan when one was published."""
        if (step_index + 1) % self.retune_interval != 0:
            return None
        return self.refresh_and_activate()


def choices_changed(old: Plan, new: Plan) -> bool:
    """Whether re-resolution actually moved any cell's concrete
    (backend, slicing_factor, allreduce_mode).  Launchers re-trace the
    step only when this is True - a refresh that merely updated the
    measured EWMAs does not invalidate the compiled step.

    A cell *grown* at a measured workload bucket counts as changed
    only when it resolves differently from what the old plan's
    nearest-bucket lookup served for that size - same resolution via a
    now-exact cell compiles to the same program."""
    def knobs(c: Optional[Choice]) -> Optional[tuple]:
        return None if c is None else (c.backend, c.slicing_factor,
                                       c.allreduce_mode)
    if set(old.entries) - set(new.entries):
        return True          # a cell disappeared: resolution may move
    for key, c in new.entries.items():
        prev = old.entries.get(key)
        if prev is None:     # grown cell: what did lookup serve here?
            prev = old.lookup(key[0], 1 << key[1], key[2],
                              level=key[3] if len(key) == 4 else None)
        if knobs(prev) != knobs(c):
            return True
    return False


def fold_measurements(plan: Plan, timings: list, *,
                      alpha: float = DEFAULT_ALPHA,
                      min_samples: int = DEFAULT_MIN_SAMPLES,
                      pool: CXLPoolConfig = CXL_POOL,
                      ib: InfiniBandConfig = INFINIBAND) -> Plan:
    """One-shot offline fold: ledger timing samples -> refreshed v4
    plan (what ``launch/tune.py --measurements`` uses)."""
    ot = OnlineTuner(plan, alpha=alpha, min_samples=min_samples,
                     pool=pool, ib=ib)
    ot.observe_timings(timings)
    return ot.refresh()
