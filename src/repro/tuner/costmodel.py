"""Unified cost oracle for the tuner.

One function predicts the completion time of any (backend, primitive,
nranks, msg_bytes, knobs) point:

* ``ring`` - the calibrated NCCL-over-InfiniBand alpha-beta model
  (``core.ibmodel``); slicing factor and allreduce mode don't apply
  (NCCL picks its own chunking).
* ``cxl``  - the event-driven pool simulator (``core.simulator``) run on
  the fully-overlapped schedule ("all" variant).  ``two_phase``
  AllReduce is costed as its actual composition: reduce_scatter(S)
  followed by all_gather(S/n), matching what ``mesh_collectives``
  executes; ``faithful`` is the paper's single-phase schedule.

Overlap-aware costing prices a collective against the compute window it
is scheduled behind (the double-buffered FSDP prefetch): the exposed
time is ``max(0, comm - overlappable_compute)``, with the overlappable
window itself bounded by roofline residency
(``roofline_compute_time``).  The sweep can minimize exposed rather
than in-isolation time, which lets ``auto`` trade wire bytes for
overlap (e.g. keep a cheaper-to-issue backend whose extra wire time is
hidden anyway).

Simulator runs are memoized - the sweep revisits (primitive, size,
nranks) many times across slicing factors and the two-phase composition
reuses the N->N runs.
"""
from __future__ import annotations

import functools
import math

from repro.core import ibmodel, simulator
from repro.core.hw import (CXL_POOL, INFINIBAND, TPU_V5E, CXLPoolConfig,
                           ICIConfig, InfiniBandConfig)
from repro.core.topology import Level


@functools.lru_cache(maxsize=65536)
def _sim_time(primitive: str, nranks: int, msg_bytes: int,
              slicing_factor: int, pool: CXLPoolConfig) -> float:
    return simulator.run_variant(
        "all", primitive, nranks, msg_bytes,
        slicing_factor=slicing_factor, pool=pool).total_time


def predict_time(backend: str, primitive: str, nranks: int, msg_bytes: int,
                 *, slicing_factor: int = 4,
                 allreduce_mode: str = "two_phase",
                 pool: CXLPoolConfig = CXL_POOL,
                 ib: InfiniBandConfig = INFINIBAND) -> float:
    """Predicted completion time (seconds) under the offline cost model."""
    if nranks <= 1:
        return 0.0
    if backend == "ring":
        return ibmodel.estimate(primitive, nranks, msg_bytes, ib).time
    if backend == "cxl":
        if primitive == "all_reduce" and allreduce_mode == "two_phase":
            rs = _sim_time("reduce_scatter", nranks, msg_bytes,
                           slicing_factor, pool)
            ag = _sim_time("all_gather", nranks,
                           max(1, msg_bytes // nranks),
                           slicing_factor, pool)
            return rs + ag
        return _sim_time(primitive, nranks, msg_bytes, slicing_factor,
                         pool)
    raise ValueError(f"unknown backend {backend!r}")


def ici_time(primitive: str, nranks: int, msg_bytes: int,
             ici: ICIConfig) -> float:
    """Ring alpha-beta estimate for an intra-node ICI level.  The ring
    step structure is fabric-agnostic, so this reuses the calibrated IB
    formulas with the ICI link constants (no copy-RDMA pipeline, so the
    per-message overhead is the hop issue cost)."""
    shim = InfiniBandConfig(link_bw=ici.link_bw,
                            efficiency=ici.efficiency,
                            message_overhead=ici.message_overhead,
                            latency=ici.latency)
    return ibmodel.estimate(primitive, nranks, msg_bytes, shim).time


def predict_level_time(level: Level, primitive: str, nranks: int,
                       msg_bytes: int, *, backend: str = "ring",
                       slicing_factor: int = 4,
                       allreduce_mode: str = "two_phase") -> float:
    """Predicted completion time of one collective on one topology
    level, priced against that level's own fabric config:

    * ``cxl`` level - ``backend='cxl'`` runs the pool simulator with the
      level's ``CXLPoolConfig``; ``backend='ring'`` is the alternative
      transport (NCCL over the level's IB config), which is what the
      tuner compares the pool against;
    * ``ib`` level - ring over the level's ``InfiniBandConfig`` (the
      pool schedule does not exist where there is no pool);
    * ``ici`` level - ring over the level's ``ICIConfig``.

    Returns ``inf`` for a backend the fabric cannot execute, so sweeps
    can enumerate candidates uniformly.
    """
    if nranks <= 1:
        return 0.0
    if backend not in ("ring", "cxl"):
        raise ValueError(f"unknown backend {backend!r}")
    if level.fabric == "cxl":
        if backend == "ring":
            return ibmodel.estimate(primitive, nranks, msg_bytes,
                                    level.ib_cfg).time
        return predict_time("cxl", primitive, nranks, msg_bytes,
                            slicing_factor=slicing_factor,
                            allreduce_mode=allreduce_mode,
                            pool=level.pool_cfg, ib=level.ib_cfg)
    if backend != "ring":
        return math.inf
    if level.fabric == "ib":
        return ibmodel.estimate(primitive, nranks, msg_bytes,
                                level.ib_cfg).time
    return ici_time(primitive, nranks, msg_bytes, level.ici_cfg)


def predict_p2p_time(backend: str, msg_bytes: int, *,
                     slicing_factor: int = 1,
                     pool: CXLPoolConfig = CXL_POOL,
                     ib: InfiniBandConfig = INFINIBAND) -> float:
    """Predicted completion time of one point-to-point hop
    (``Communicator.send``: the full payload moves exactly one ring
    hop).  The collective oracles don't apply - a p2p is not a ring
    program, it is one producer/consumer pair:

    * ``cxl`` - the pool handoff of ``core/doorbell.py``: the producer
      writes the payload (bounded by the slower of the device and
      server caps), rings the doorbell (flush + cross-socket
      visibility), the consumer invalidates/polls and reads it back
      out.  Chunking by the slicing factor pipelines the consumer read
      behind the producer write - each extra chunk costs another
      doorbell ring + poll, so the sweep's argmin over factors finds
      the paper-style chunking sweet spot.
    * ``ring`` - one direct alpha-beta NIC transfer (no copy-RDMA
      chain to pipeline against, so chunking only adds per-message
      overhead).
    """
    s = max(0, int(msg_bytes))
    if s == 0:
        return 0.0
    f = max(1, int(slicing_factor))
    if backend == "cxl":
        bw = min(pool.device_bw, pool.server_bw)
        chunk = s / f
        # producer writes stream; the consumer's read of chunk k
        # overlaps the write of k+1, exposing only the last chunk's
        # read; every chunk pays its own doorbell ring + poll.
        return (pool.memcpy_overhead + s / bw + chunk / bw
                + f * (pool.doorbell_latency + pool.poll_interval)
                + pool.access_latency)
    if backend == "ring":
        return (ib.latency + f * ib.message_overhead
                + s / ib.effective_bw)
    raise ValueError(f"unknown backend {backend!r}")


def predict_level_p2p_time(level: Level, msg_bytes: int, *,
                           backend: str = "ring",
                           slicing_factor: int = 1) -> float:
    """One p2p hop priced against a topology level's own fabric config
    (the p2p analog of ``predict_level_time``):

    * ``cxl`` level - ``backend='cxl'`` is the pool write + doorbell
      commit with the level's ``CXLPoolConfig``; ``backend='ring'`` is
      the alternative transport over the level's IB config;
    * ``ib`` / ``ici`` level - ring only (the pool handoff does not
      exist off the pool); returns ``inf`` for ``cxl`` so sweeps can
      enumerate candidates uniformly.
    """
    if backend not in ("ring", "cxl"):
        raise ValueError(f"unknown backend {backend!r}")
    if level.fabric == "cxl":
        return predict_p2p_time(backend, msg_bytes,
                                slicing_factor=slicing_factor,
                                pool=level.pool_cfg, ib=level.ib_cfg)
    if backend != "ring":
        return math.inf
    if level.fabric == "ib":
        return predict_p2p_time("ring", msg_bytes,
                                slicing_factor=slicing_factor,
                                ib=level.ib_cfg)
    ici = level.ici_cfg
    shim = InfiniBandConfig(link_bw=ici.link_bw,
                            efficiency=ici.efficiency,
                            message_overhead=ici.message_overhead,
                            latency=ici.latency)
    return predict_p2p_time("ring", msg_bytes,
                            slicing_factor=slicing_factor, ib=shim)


def roofline_compute_time(flops: float, hbm_bytes: float = 0.0, *,
                          peak_flops: float = TPU_V5E.peak_flops_bf16,
                          hbm_bw: float = TPU_V5E.hbm_bw) -> float:
    """Roofline residency of a compute region: the window a collective
    can hide behind is bounded by whichever resource the region
    saturates (MXU or HBM), not by wall-clock guesses."""
    if flops < 0 or hbm_bytes < 0:
        raise ValueError("flops/bytes must be non-negative")
    return max(flops / peak_flops, hbm_bytes / hbm_bw)


def epilogue_flops(primitive: str, msg_bytes: int) -> float:
    """FLOPs of the epilogue/prologue compute a fused collective kernel
    folds into the transfer (``kernels.fused_collectives``), per
    message byte: ~2 flops per f32 element covers both shipped fusions
    (rmsnorm: square + multiply-add per element; AdamW: a handful of
    FMAs per element - same order).  A primitive with no fused kernel
    contributes nothing."""
    if primitive not in ("reduce_scatter", "all_gather"):
        return 0.0
    return 2.0 * (max(0, int(msg_bytes)) / 4.0)


def fused_window(primitive: str, msg_bytes: int, base_window: float, *,
                 peak_flops: float = TPU_V5E.peak_flops_bf16,
                 hbm_bw: float = TPU_V5E.hbm_bw) -> float:
    """The overlap window of a *fused* candidate: the unfused window
    plus the roofline residency of the epilogue the fusion absorbs into
    the transfer.  Fusing also deletes the epilogue's HBM round-trip on
    the collective's payload (the unfused composition writes the
    reduced segment and reads it straight back: 2x msg_bytes), so that
    traffic counts toward the hidden window too.  Returns
    ``base_window`` unchanged for primitives with no fused kernel."""
    fl = epilogue_flops(primitive, msg_bytes)
    if fl <= 0.0:
        return max(0.0, base_window)
    extra = roofline_compute_time(fl, 2.0 * max(0, int(msg_bytes)),
                                  peak_flops=peak_flops, hbm_bw=hbm_bw)
    return max(0.0, base_window) + extra


def predict_exposed_time(backend: str, primitive: str, nranks: int,
                         msg_bytes: int, *,
                         overlappable_compute: float = 0.0,
                         slicing_factor: int = 4,
                         allreduce_mode: str = "two_phase",
                         pool: CXLPoolConfig = CXL_POOL,
                         ib: InfiniBandConfig = INFINIBAND) -> float:
    """Exposed (non-hidden) time of a collective scheduled behind
    ``overlappable_compute`` seconds of independent compute:
    ``max(0, comm - overlappable_compute)``."""
    t = predict_time(backend, primitive, nranks, msg_bytes,
                     slicing_factor=slicing_factor,
                     allreduce_mode=allreduce_mode, pool=pool, ib=ib)
    return max(0.0, t - max(0.0, overlappable_compute))


def cache_clear() -> None:
    _sim_time.cache_clear()
