"""Unified cost oracle for the tuner.

One function predicts the completion time of any (backend, primitive,
nranks, msg_bytes, knobs) point:

* ``ring`` - the calibrated NCCL-over-InfiniBand alpha-beta model
  (``core.ibmodel``); slicing factor and allreduce mode don't apply
  (NCCL picks its own chunking).
* ``cxl``  - the event-driven pool simulator (``core.simulator``) run on
  the fully-overlapped schedule ("all" variant).  ``two_phase``
  AllReduce is costed as its actual composition: reduce_scatter(S)
  followed by all_gather(S/n), matching what ``mesh_collectives``
  executes; ``faithful`` is the paper's single-phase schedule.

Simulator runs are memoized - the sweep revisits (primitive, size,
nranks) many times across slicing factors and the two-phase composition
reuses the N->N runs.
"""
from __future__ import annotations

import functools

from repro.core import ibmodel, simulator
from repro.core.hw import (CXL_POOL, INFINIBAND, CXLPoolConfig,
                           InfiniBandConfig)


@functools.lru_cache(maxsize=65536)
def _sim_time(primitive: str, nranks: int, msg_bytes: int,
              slicing_factor: int, pool: CXLPoolConfig) -> float:
    return simulator.run_variant(
        "all", primitive, nranks, msg_bytes,
        slicing_factor=slicing_factor, pool=pool).total_time


def predict_time(backend: str, primitive: str, nranks: int, msg_bytes: int,
                 *, slicing_factor: int = 4,
                 allreduce_mode: str = "two_phase",
                 pool: CXLPoolConfig = CXL_POOL,
                 ib: InfiniBandConfig = INFINIBAND) -> float:
    """Predicted completion time (seconds) under the offline cost model."""
    if nranks <= 1:
        return 0.0
    if backend == "ring":
        return ibmodel.estimate(primitive, nranks, msg_bytes, ib).time
    if backend == "cxl":
        if primitive == "all_reduce" and allreduce_mode == "two_phase":
            rs = _sim_time("reduce_scatter", nranks, msg_bytes,
                           slicing_factor, pool)
            ag = _sim_time("all_gather", nranks,
                           max(1, msg_bytes // nranks),
                           slicing_factor, pool)
            return rs + ag
        return _sim_time(primitive, nranks, msg_bytes, slicing_factor,
                         pool)
    raise ValueError(f"unknown backend {backend!r}")


def cache_clear() -> None:
    _sim_time.cache_clear()
