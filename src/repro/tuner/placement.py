"""Topology-aware placement planner (ROADMAP: "topology-aware
placement").

PR 3 made the hierarchy first-class, but the mesh->fabric assignment
stayed hand-written: the user decided that the FSDP axis rides the
rack-scale CXL pool and the TP axis the intra-node ring.  This module
chooses that assignment from the *workload*: given a model's collective
mix (per-axis wire bytes, primitive counts and overlap windows - from a
dry-run profile or the analytic model) and a ``core.topology.Topology``,
it enumerates every axis<->level assignment (including splits of one
logical axis across adjacent levels, and ragged levels priced with
their cross-group parent fabric) and prices each with the tuner's own
per-level oracles (``costmodel.predict_level_time``), minimizing the
predicted *exposed* communication time per step::

    exposed(call) = max(0, wire_time - overlap_window) * calls_per_step

The result is a ranked :class:`PlacementPlan`.  Launchers apply the
best placement when building the mesh (``train/serve/dryrun
--placement auto``): the mesh axes are ordered by the levels they were
assigned to, the placed topology relabels those levels with the logical
axis names (topology fingerprints ignore axis names, so an existing
tuned plan keeps matching), and split axes resolve through the
``models.sharding`` axis-alias indirection - model code never changes.

Entry points
------------
``CollectiveMix.for_model``   analytic per-axis traffic for an arch
``CollectiveMix.from_dryrun`` per-axis traffic from a dry-run record
``plan_placement``            mix + topology -> ranked PlacementPlan
``placed_topology``           relabel levels with the assigned axes
``mesh_spec``                 (shape, axis names, aliases) for the mesh
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from typing import Optional, Sequence

from repro.core.topology import Level, Topology
from repro.tuner import costmodel

# Primitives whose hierarchical decomposition we price exactly; the
# rest fall back to per-level recursion at the full payload.
_EXACT = ("all_reduce", "all_gather", "reduce_scatter", "broadcast")


# --------------------------------------------------------------------- #
# the collective mix
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class CollectiveCall:
    """One collective call site on a logical axis, per training step."""

    primitive: str
    msg_bytes: int          # per-rank payload, the repo-wide convention
    calls: float = 1.0      # launches per step (trip-count scaled)
    overlap_s: float = 0.0  # compute window one launch can hide behind

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class AxisTraffic:
    """A logical mesh axis: its required parallel degree and the
    per-step collective traffic the model issues over it."""

    axis: str
    size: int
    calls: tuple = ()       # of CollectiveCall

    @property
    def bytes_per_step(self) -> float:
        return sum(c.msg_bytes * c.calls for c in self.calls)


@dataclasses.dataclass(frozen=True)
class CollectiveMix:
    """The model's whole per-step collective traffic, split per logical
    axis - the workload half of the placement problem."""

    axes: tuple             # of AxisTraffic, any order

    def axis(self, name: str) -> AxisTraffic:
        for a in self.axes:
            if a.axis == name:
                return a
        raise KeyError(name)

    @classmethod
    def for_model(cls, cfg, axes: dict, *, seq: int = 4096,
                  batch_per_rank: int = 8, param_bytes: int = 2,
                  act_bytes: int = 2, tp_axis: str = "model",
                  overlap_gathers: bool = True,
                  pp_axis: Optional[str] = None,
                  microbatches: int = 8) -> "CollectiveMix":
        """Analytic mix for a model config on logical ``axes``
        (``{"data": fsdp_degree, "model": tp_degree, "stage": pp}``).

        Per layer and step: the TP axis carries 4 activation
        AllReduces (attention + MLP output, forward and backward);
        every other axis is FSDP-style - 2 parameter AllGathers
        (forward + backward) and one gradient ReduceScatter of the
        layer's parameter bytes.  With ``overlap_gathers`` the gathers
        get the roofline residency of one layer's compute as their
        overlap window (the double-buffered prefetch of
        ``core.overlap`` hides them behind the previous layer).

        A pipeline axis (``pp_axis`` of degree ``p > 1``) carries the
        stage handoff instead: ``2 * microbatches`` p2p hops per step
        (forward activations + backward grads), each one microbatch's
        activation slab.  Pipelining also shrinks every *other* axis's
        per-layer traffic by ``1/p`` - a rank owns only its stage's
        slice of the stack, which is exactly why a PP x FSDP placement
        can beat FSDP-only at the same device count.
        """
        n_layers = max(1, cfg.n_layers)
        pp = int(axes.get(pp_axis, 1)) if pp_axis else 1
        local_layers = n_layers / max(1, pp)
        layer_bytes = int(cfg.param_count() // n_layers) * param_bytes
        act = batch_per_rank * seq * cfg.d_model * act_bytes
        # fwd+bwd FLOPs of one layer's matmuls on this rank's tokens
        layer_flops = 6.0 * (cfg.param_count() / n_layers) \
            * batch_per_rank * seq
        window = costmodel.roofline_compute_time(layer_flops) \
            if overlap_gathers else 0.0
        loads = []
        for name, size in axes.items():
            if size <= 1:
                # kept (traffic-free) so the mesh still carries the axis
                loads.append(AxisTraffic(name, int(size), ()))
                continue
            if pp_axis is not None and name == pp_axis:
                calls = (CollectiveCall(
                    "p2p", max(1, act // max(1, microbatches)),
                    calls=2.0 * microbatches),)
            elif name == tp_axis:
                calls = (CollectiveCall("all_reduce", act,
                                        calls=4.0 * local_layers),)
            else:
                calls = (CollectiveCall("all_gather",
                                        layer_bytes // max(1, size),
                                        calls=2.0 * local_layers,
                                        overlap_s=window),
                         CollectiveCall("reduce_scatter", layer_bytes,
                                        calls=1.0 * local_layers))
            loads.append(AxisTraffic(name, int(size), calls))
        if not any(a.size > 1 for a in loads):
            raise ValueError(f"no axis with size > 1 in {axes}")
        return cls(axes=tuple(loads))

    @classmethod
    def from_dryrun(cls, record: dict,
                    axis_sizes: Optional[dict] = None) -> "CollectiveMix":
        """Mix from a dry-run JSON record's ``auto_choices`` audit
        (``launch/dryrun --backend auto``).  Entries tagged with a
        topology level aggregate per level axis; untagged entries are
        attributed to the axis in ``axis_sizes`` (``{name: size}``)
        whose size matches their rank count."""
        choices = (record.get("ledger") or {}).get("auto_choices") or []
        sizes = dict(axis_sizes or {})
        per_axis: dict = {}
        nranks_seen: dict = {}
        for ch in choices:
            ax = ch.get("level")
            if ax is None:
                for name, size in sizes.items():
                    if size == ch["nranks"]:
                        ax = name
                        break
            if ax is None:
                continue
            per_axis.setdefault(ax, []).append(CollectiveCall(
                ch["primitive"], int(ch["msg_bytes"]),
                calls=float(ch.get("calls", 1.0)),
                overlap_s=0.0))
            nranks_seen[ax] = max(nranks_seen.get(ax, 1),
                                  int(ch["nranks"]))
        loads = [AxisTraffic(ax, int(sizes.get(ax, nranks_seen[ax])),
                             tuple(calls))
                 for ax, calls in per_axis.items()]
        if not loads:
            raise ValueError(
                "record carries no attributable auto_choices (run the "
                "dry-run with --backend auto, and either a --topology "
                "or pass axis_sizes)")
        return cls(axes=tuple(loads))


# --------------------------------------------------------------------- #
# pricing one axis on one run of levels
# --------------------------------------------------------------------- #

def _link_penalty(level: Level, backend: str,
                  penalties: Optional[dict]) -> float:
    """Measured-slowdown multiplier for pricing ``backend`` on
    ``level``.  Keys are "axis/fabric" (the link-health registry's
    keying) or a bare fabric kind.  On a cxl level the ``ring``
    backend is exempt: it rides the level's *alternative IB transport*
    (``level.ib_cfg``), which does not share the pool's fault - that
    exemption is what makes a penalized ranking fail over instead of
    writing the level off."""
    if not penalties:
        return 1.0
    if level.fabric == "cxl" and backend == "ring":
        return 1.0
    f = penalties.get(f"{level.axis}/{level.fabric}",
                      penalties.get(level.fabric, 1.0))
    return max(1.0, float(f))


def _best_level_time(level: Level, primitive: str, nranks: int,
                     msg_bytes: int,
                     penalties: Optional[dict] = None) -> float:
    """Cheapest backend the fabric can execute, under the level's own
    oracle (times any measured link penalty) - what the per-level
    tuner sweep would resolve to."""
    if nranks <= 1 or msg_bytes <= 0:
        return 0.0
    s = max(1, int(msg_bytes))
    if primitive == "p2p":
        # one full-payload hop; cxl sweeps the doorbell-chunking factor
        # exactly as the plan sweep does (costmodel.predict_p2p_time)
        best = math.inf
        for b in level.backends():
            factors = (1, 2, 4, 8, 16) if b == "cxl" else (1,)
            t = min(costmodel.predict_level_p2p_time(
                level, s, backend=b, slicing_factor=f) for f in factors)
            best = min(best, t * _link_penalty(level, b, penalties))
        return best
    return min(costmodel.predict_level_time(
        level, primitive, nranks, s, backend=b)
        * _link_penalty(level, b, penalties)
        for b in level.backends())


def _ragged_call_time(level: Level, parent: Optional[Level],
                      primitive: str, msg_bytes: int,
                      penalties: Optional[dict] = None) -> float:
    """Predicted wire time of one collective on a ragged level: the
    grouped decomposition the Communicator actually runs (within-group
    schedule on this fabric, sub-root exchange on the parent fabric)."""
    shape = level.shape
    s = max(1, int(msg_bytes))
    max_g, n_g, n = max(shape), len(shape), sum(shape)
    p = parent if parent is not None else level
    pen = penalties
    if primitive == "all_reduce":
        return (_best_level_time(level, "all_reduce", max_g, s, pen)
                + _best_level_time(p, "all_reduce", n_g, s, pen)
                + _best_level_time(level, "broadcast", max_g, s, pen))
    if primitive in ("all_gather", "gather"):
        return (_best_level_time(level, "all_gather", max_g, s, pen)
                + _best_level_time(p, "all_gather", n_g, s * max_g, pen)
                + _best_level_time(level, "broadcast", max_g, s * n, pen))
    # flat single-axis fallback (what the Communicator executes for
    # the remaining primitives): all n ranks on whichever fabric is
    # slower - cross-group hops physically ride the parent fabric.
    return max(_best_level_time(level, primitive, n, s, pen),
               _best_level_time(p, primitive, n, s, pen))


def _run_call_time(levels_sizes: Sequence[tuple], primitive: str,
                   msg_bytes: int,
                   parents: Optional[dict] = None,
                   penalties: Optional[dict] = None) -> float:
    """Predicted wire time of one collective on a run of levels
    (outermost first).  Single-level runs dispatch directly (ragged
    levels via the grouped decomposition); multi-level runs price the
    hierarchical decomposition the Communicator lowers tuple axes to.
    """
    s = max(1, int(msg_bytes))
    pen = penalties
    if primitive == "p2p":
        # the ring hop moves the full payload over exactly one link per
        # tick: a split or grouped axis is gated by the slowest boundary
        # a neighbor pair crosses, never the sum of the fabrics
        times = []
        for lv, n in levels_sizes:
            t = _best_level_time(lv, "p2p", n, s, pen)
            if lv.grouped:
                parent = (parents or {}).get(lv.axis) or lv
                t = max(t, _best_level_time(parent, "p2p", 2, s, pen))
            times.append(t)
        return max(times)
    if len(levels_sizes) == 1:
        level, n = levels_sizes[0]
        if level.grouped:
            parent = (parents or {}).get(level.axis)
            return _ragged_call_time(level, parent, primitive, s, pen)
        return _best_level_time(level, primitive, n, s, pen)
    outer, n0 = levels_sizes[0]
    inner = list(levels_sizes[1:])
    prod_inner = 1
    for _, n in inner:
        prod_inner *= n
    if primitive == "all_reduce":
        # RS down the inner levels, AR across the outer on the shard,
        # AG back out (mc.hierarchical_all_reduce)
        t, seg = 0.0, float(s)
        for lv, n in reversed(inner):
            t += _best_level_time(lv, "reduce_scatter", n, int(seg), pen)
            seg /= n
        t += _best_level_time(outer, "all_reduce", n0, int(seg), pen)
        for lv, n in inner:
            t += _best_level_time(lv, "all_gather", n, int(seg), pen)
            seg *= n
        return t
    if primitive == "all_gather":
        # inner (minor) level first, payload grows level by level
        t, seg = 0.0, float(s)
        for lv, n in reversed(levels_sizes):
            t += _best_level_time(lv, "all_gather", n, int(seg), pen)
            seg *= n
        return t
    if primitive == "reduce_scatter":
        # outer level first, payload shrinks before the next fabric
        t, seg = 0.0, float(s)
        for lv, n in levels_sizes:
            t += _best_level_time(lv, "reduce_scatter", n, int(seg), pen)
            seg /= n
        return t
    if primitive == "broadcast":
        # scatter in the root's inner group, cross-outer broadcast of
        # the 1/prod(inner) pieces, allgather within every inner group
        t = 0.0
        for lv, n in inner:
            t += _best_level_time(lv, "scatter", n, s, pen)
        t += _best_level_time(outer, "broadcast", n0,
                              max(1, s // prod_inner), pen)
        for lv, n in inner:
            t += _best_level_time(lv, "all_gather", n,
                                  max(1, s // prod_inner), pen)
        return t
    # rooted recursion: full payload per level (conservative)
    return sum(_best_level_time(lv, primitive, n, s, pen)
               for lv, n in levels_sizes)


def _axis_time(traffic: AxisTraffic, levels_sizes: Sequence[tuple],
               parents: dict,
               penalties: Optional[dict] = None) -> float:
    """Predicted exposed seconds/step of one axis's traffic on a run."""
    total = 0.0
    for c in traffic.calls:
        wire = _run_call_time(levels_sizes, c.primitive, c.msg_bytes,
                              parents=parents, penalties=penalties)
        total += max(0.0, wire - max(0.0, c.overlap_s)) * c.calls
    return total


def predict_call_time(topology: Topology, axis: str, primitive: str,
                      msg_bytes: int,
                      penalties: Optional[dict] = None) -> float:
    """Public single-call pricing: predicted wire seconds of one
    collective over ``axis``'s level (ragged levels priced as the
    grouped decomposition the Communicator actually runs), with
    optional measured link penalties.  This is what the resilience
    layer uses to compare a survivor/failover schedule's step time
    against the healthy one without executing either."""
    lv = topology.level_for(axis)
    if lv is None:
        raise KeyError(f"no level for axis {axis!r}")
    parents = {lv.axis: topology.parent_of(lv.axis)}
    n = lv.size if lv.size is not None else 2
    return _run_call_time(((lv, n),), primitive, msg_bytes,
                          parents=parents, penalties=penalties)


# --------------------------------------------------------------------- #
# assignment enumeration
# --------------------------------------------------------------------- #

def _absorbed(levels: Sequence[Level], i: int) -> bool:
    """A level immediately followed by a grouped level is its virtual
    cross-group parent: it is consumed by the ragged decomposition and
    cannot carry a mesh axis of its own."""
    return i + 1 < len(levels) and levels[i + 1].grouped


def _run_feasible(levels: Sequence[Level], idxs: Sequence[int],
                  size: int) -> Optional[tuple]:
    """Level sizes for a run carrying an axis of ``size`` ranks, or
    None when infeasible.  Single-level runs accept an undeclared size
    (the mesh axis supplies it); multi-level runs need every level's
    size declared so the mesh factorization is unambiguous, and a
    grouped level never joins a multi-level run (it already spans two
    fabrics)."""
    run = [levels[i] for i in idxs]
    if len(run) == 1:
        lv = run[0]
        if lv.size is not None and lv.size != size:
            return None
        return ((lv, size),)
    if any(lv.grouped or lv.size is None for lv in run):
        return None
    prod = 1
    for lv in run:
        prod *= lv.size
    if prod != size:
        return None
    return tuple((lv, lv.size) for lv in run)


def _assignments(levels: Sequence[Level], axes: Sequence[AxisTraffic]):
    """Yield every assignment of axes to disjoint contiguous runs of
    placeable levels (unused levels allowed), as tuples of
    ``(AxisTraffic, level index tuple)`` ordered outermost first."""
    placeable = [i for i in range(len(levels))
                 if not _absorbed(levels, i)]

    def rec(pos, remaining, acc):
        if not remaining:
            yield tuple(acc)
            return
        if pos >= len(placeable):
            return
        # leave this level unused
        yield from rec(pos + 1, remaining, acc)
        # or start a run here for one of the remaining axes
        for k, a in enumerate(remaining):
            for run_len in range(1, len(placeable) - pos + 1):
                idxs = placeable[pos:pos + run_len]
                if idxs != list(range(idxs[0], idxs[0] + run_len)):
                    break   # runs must be adjacent levels
                sizes = _run_feasible(levels, idxs, a.size)
                if sizes is None:
                    continue
                acc.append((a, tuple(idxs)))
                yield from rec(pos + run_len,
                               remaining[:k] + remaining[k + 1:], acc)
                acc.pop()

    yield from rec(0, tuple(axes), [])


# --------------------------------------------------------------------- #
# the plan
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Placement:
    """One scored axis->level assignment.  ``assignment`` is ordered
    outermost level first: ``((axis name, (level axis, ...)), ...)``;
    a multi-level entry is an axis split across adjacent levels."""

    assignment: tuple
    predicted_exposed_s: float
    per_axis_s: tuple      # ((axis name, seconds), ...)

    def levels_for(self, axis: str) -> Optional[tuple]:
        for name, levels in self.assignment:
            if name == axis:
                return levels
        return None

    @property
    def split_axes(self) -> tuple:
        return tuple(name for name, levels in self.assignment
                     if len(levels) > 1)

    def describe(self) -> str:
        return ", ".join(f"{name}->{'+'.join(levels)}"
                         for name, levels in self.assignment)

    def to_json(self) -> dict:
        return {"assignment": [{"axis": n, "levels": list(ls)}
                               for n, ls in self.assignment],
                "predicted_exposed_s": self.predicted_exposed_s,
                "per_axis_s": {n: t for n, t in self.per_axis_s}}

    @classmethod
    def from_json(cls, doc: dict) -> "Placement":
        return cls(
            assignment=tuple((e["axis"], tuple(e["levels"]))
                             for e in doc["assignment"]),
            predicted_exposed_s=float(doc["predicted_exposed_s"]),
            per_axis_s=tuple(sorted(doc.get("per_axis_s", {}).items())))


@dataclasses.dataclass
class PlacementPlan:
    """Ranked placements (ascending predicted exposed step time) for
    one (collective mix, topology) pair - the placement analog of the
    tuner's ``Plan``."""

    topology: Topology
    ranked: tuple           # of Placement, best first
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def best(self) -> Placement:
        return self.ranked[0]

    def best_with_unsplit(self, axes: Sequence[str]) -> Placement:
        """Best placement that keeps every axis in ``axes`` on a single
        level - what launchers apply when an axis's collectives cannot
        span a tuple axis (e.g. in-row TP AllReduces).  Raises
        ``ValueError`` when every feasible assignment splits one of
        them: applying a split placement anyway would build a mesh
        without the axis the model expects."""
        for p in self.ranked:
            if all(len(p.levels_for(a) or ("x",)) == 1 for a in axes):
                return p
        raise ValueError(
            f"every feasible placement splits one of {tuple(axes)} "
            f"across levels (candidates: "
            f"{[p.describe() for p in self.ranked[:5]]}); declare a "
            f"level whose size matches the axis degree, or change the "
            f"mesh degrees")

    def find(self, assignment: dict) -> Optional[Placement]:
        """The ranked entry matching ``{axis: (level, ...)}`` (levels a
        name or tuple of names), e.g. the hand-tuned assignment a
        benchmark compares against."""
        want = {a: (ls,) if isinstance(ls, str) else tuple(ls)
                for a, ls in assignment.items()}
        for p in self.ranked:
            if dict(p.assignment) == want:
                return p
        return None

    def to_json(self) -> dict:
        return {"topology": self.topology.to_json(),
                "topology_fingerprint": self.topology.fingerprint(),
                "meta": self.meta,
                "ranked": [p.to_json() for p in self.ranked]}

    @classmethod
    def from_json(cls, doc: dict) -> "PlacementPlan":
        return cls(topology=Topology.from_json(doc["topology"]),
                   ranked=tuple(Placement.from_json(p)
                                for p in doc["ranked"]),
                   meta=dict(doc.get("meta", {})))


def save_placement(plan: PlacementPlan, path: str) -> None:
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(plan.to_json(), f, indent=1, sort_keys=True)


def load_placement(path: str) -> PlacementPlan:
    with open(path) as f:
        return PlacementPlan.from_json(json.load(f))


def plan_placement(mix: CollectiveMix, topology: Topology, *,
                   top_k: Optional[int] = None,
                   link_penalties: Optional[dict] = None
                   ) -> PlacementPlan:
    """Enumerate and rank every feasible axis->level assignment.

    Each candidate is priced per axis with the tuner's per-level
    oracles: a single-level run at the axis's degree, a multi-level
    run as the hierarchical decomposition the Communicator lowers
    tuple axes to, and a ragged level as its grouped decomposition
    (cross-group sub-root traffic on the parent level's fabric).
    ``link_penalties`` ("axis/fabric" or bare fabric -> measured
    slowdown multiplier, e.g. from ``tuner.runtime.get_link_health``)
    re-ranks candidates against the fabric as it measures *now*: a
    degraded pool loses its cells to the level's IB alternative or to
    another level entirely.  Raises ``ValueError`` when no assignment
    fits (axis degrees vs declared level sizes).
    """
    levels = topology.levels
    parents = {lv.axis: topology.parent_of(lv.axis) for lv in levels}
    # size-1 axes carry no traffic and need no fabric level; the mesh
    # still gets them (innermost) via mesh_spec
    place_axes = tuple(a for a in mix.axes if a.size > 1)
    scored = []
    seen = set()
    for assign in _assignments(levels, place_axes):
        key = tuple(sorted((a.axis, idxs) for a, idxs in assign))
        if key in seen:
            continue
        seen.add(key)
        per_axis = []
        total = 0.0
        for a, idxs in assign:
            sizes = _run_feasible(levels, idxs, a.size)
            t = _axis_time(a, sizes, parents, penalties=link_penalties)
            per_axis.append((a.axis, t))
            total += t
        ordered = sorted(assign, key=lambda e: e[1][0])
        scored.append(Placement(
            assignment=tuple((a.axis,
                              tuple(levels[i].axis for i in idxs))
                             for a, idxs in ordered),
            predicted_exposed_s=total,
            per_axis_s=tuple(sorted(per_axis))))
    if not scored:
        raise ValueError(
            f"no feasible axis->level assignment: axes "
            f"{[(a.axis, a.size) for a in mix.axes]} vs levels "
            f"{[(lv.axis, lv.size) for lv in levels]}")
    scored.sort(key=lambda p: (p.predicted_exposed_s, p.describe()))
    if top_k is not None:
        scored = scored[:top_k]
    return PlacementPlan(
        topology=topology, ranked=tuple(scored),
        meta={"axes": {a.axis: a.size for a in mix.axes},
              "bytes_per_step": {a.axis: a.bytes_per_step
                                 for a in mix.axes},
              **({"link_penalties": {k: float(v) for k, v
                                     in link_penalties.items()}}
                 if link_penalties else {})})


# --------------------------------------------------------------------- #
# applying a placement
# --------------------------------------------------------------------- #

def placed_topology(placement: Placement,
                    topology: Topology) -> Topology:
    """Relabel the assigned levels with the logical axis names so the
    runtime decomposes the placed mesh against them.  Split axes keep
    the physical level names (the mesh carries one axis per level,
    bridged by the ``models.sharding`` aliases); absorbed cross-group
    parents and unused levels keep their names too.  Because topology
    fingerprints ignore axis names, a plan tuned against the physical
    topology still matches the relabeled one."""
    renames = {}
    for axis, level_names in placement.assignment:
        if len(level_names) == 1:
            renames[level_names[0]] = axis
    new = tuple(dataclasses.replace(lv, axis=renames.get(lv.axis,
                                                         lv.axis))
                for lv in topology.levels)
    return Topology(levels=new)


def mesh_spec(placement: Placement, mix: CollectiveMix,
              topology: Topology) -> tuple:
    """(axis sizes, axis names, aliases) for ``jax.make_mesh``, ordered
    outermost level first.  Single-level axes keep their logical name;
    a split axis contributes one mesh axis per level (named after the
    level) plus an alias ``logical -> (level, ...)`` for
    ``models.sharding.set_axis_aliases``.  A ragged level's axis spans
    ``sum(shape)`` ranks flat."""
    shape, names = [], []
    aliases = {}
    for axis, level_names in placement.assignment:
        traffic = mix.axis(axis)
        if len(level_names) == 1:
            names.append(axis)
            shape.append(traffic.size)
        else:
            aliases[axis] = tuple(level_names)
            for ln in level_names:
                lv = topology.level_for(ln)
                names.append(ln)
                shape.append(lv.size)
    # traffic-free size-1 axes ride innermost so model code still finds
    # its named axes in the mesh
    placed = {n for n, _ in placement.assignment}
    for a in mix.axes:
        if a.axis not in placed:
            names.append(a.axis)
            shape.append(a.size)
    return tuple(shape), tuple(names), aliases


def format_report(plan: PlacementPlan, top: int = 5,
                  chosen: Optional[Placement] = None) -> str:
    """Human-readable ranked table for launcher/CLI output.  ``chosen``
    marks the placement the caller actually applies (launchers pick
    ``best_with_unsplit``, which is not always rank #0); default: the
    top-ranked one."""
    chosen = chosen if chosen is not None else plan.ranked[0]
    lines = ["placement  (predicted exposed comm s/step, best first)"]
    shown = False
    for i, p in enumerate(plan.ranked[:top]):
        mark = " <- chosen" if p == chosen else ""
        shown = shown or bool(mark)
        per = ", ".join(f"{a}={t:.3e}" for a, t in p.per_axis_s)
        lines.append(f"  #{i} {p.describe():40s} "
                     f"{p.predicted_exposed_s:.3e}s  [{per}]{mark}")
    if len(plan.ranked) > top:
        lines.append(f"  ... {len(plan.ranked) - top} more candidates")
    if not shown:
        lines.append(f"  chosen (below top {top}): {chosen.describe()}")
    return "\n".join(lines)
