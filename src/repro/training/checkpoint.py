"""Checkpointing: pytree save/restore without external deps.

Disk layout: ``<dir>/step_<n>/arrays.npz`` (flattened leaves, keyed by
index) plus ``tree.json`` (the treedef paths + leaf dtypes/shapes) and
``meta.json``.  Restore rebuilds the exact pytree and validates shapes.
``save`` is atomic: the snapshot is staged into ``step_<n>.tmp`` and
renamed into place, so a rank dying mid-save never leaves a corrupt
*latest* checkpoint — ``latest_step``/``restore`` skip ``.tmp``
leftovers.

``PoolCheckpointStore`` is the pool-resident variant: double-buffered
snapshot slots in CXL pool memory, committed by a doorbell ring, priced
by the pool cost model, so a restarted or re-admitted rank rejoins warm
from pooled memory instead of cold disk.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any, Callable, Optional

import jax
import numpy as np
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path

from repro.core import pool as pool_mod
from repro.core.doorbell import DoorbellRegion
from repro.core.hw import CXLPoolConfig


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree: Any,
         meta: Optional[dict] = None) -> str:
    """Atomic save: stage into ``step_<n>.tmp``, rename into place."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + ".tmp"
    if os.path.isdir(tmp):  # stale leftover from a died rank
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat, treedef = tree_flatten_with_path(tree)
    arrays = {}
    index = []
    for i, (path, leaf) in enumerate(flat):
        arrays[f"a{i}"] = np.asarray(leaf)
        index.append(_path_str(path))
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "tree.json"), "w") as f:
        json.dump({"paths": index}, f)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    if os.path.isdir(out):  # re-save of the same step
        shutil.rmtree(out)
    os.rename(tmp, out)  # the commit point
    return out


def _step_of(name: str) -> Optional[int]:
    """Step index of a committed checkpoint dir name, else None
    (``.tmp`` staging leftovers and strangers are not checkpoints)."""
    if not name.startswith("step_") or name.endswith(".tmp"):
        return None
    try:
        return int(name.split("_", 1)[1])
    except ValueError:
        return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [s for d in os.listdir(ckpt_dir)
             if (s := _step_of(d)) is not None]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates paths+shapes).

    Only committed checkpoints are eligible; a ``step_<n>.tmp``
    leftover from an interrupted save is never read."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.isdir(src):
        hint = (" (a .tmp staging dir exists: the save was interrupted "
                "before commit)" if os.path.isdir(src + ".tmp") else "")
        raise FileNotFoundError(f"no committed checkpoint at {src}{hint}")
    with open(os.path.join(src, "tree.json")) as f:
        saved_paths = json.load(f)["paths"]
    data = np.load(os.path.join(src, "arrays.npz"))
    flat, treedef = tree_flatten_with_path(like)
    if len(flat) != len(saved_paths):
        raise ValueError(
            f"checkpoint has {len(saved_paths)} leaves, target structure "
            f"has {len(flat)}")
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        ps = _path_str(path)
        if ps != saved_paths[i]:
            raise ValueError(
                f"leaf {i} path mismatch: checkpoint {saved_paths[i]!r} "
                f"vs target {ps!r}")
        arr = data[f"a{i}"]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{ps}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def load_meta(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Pool-resident checkpointing


def _serialize_tree(step: int, tree: Any,
                    meta: Optional[dict]) -> tuple[bytes, bytes]:
    """(header, payload): a self-describing snapshot byte image."""
    flat, _ = tree_flatten_with_path(tree)
    leaves, entries, off = [], [], 0
    for path, leaf in flat:
        arr = np.ascontiguousarray(np.asarray(leaf))
        raw = arr.tobytes()
        entries.append({"path": _path_str(path), "dtype": str(arr.dtype),
                        "shape": list(arr.shape), "offset": off,
                        "nbytes": len(raw)})
        leaves.append(raw)
        off += len(raw)
    header = json.dumps({"step": step, "leaves": entries,
                         "meta": meta or {}}).encode()
    return header, b"".join(leaves)


@dataclasses.dataclass
class PoolCheckpointStore:
    """Double-buffered, doorbell-committed snapshots in CXL pool memory.

    Layout (paper-style index calculation, no allocator): the region
    begins with a ``DoorbellRegion`` of ``slots`` commit words; the
    remaining capacity is split into ``slots`` equal snapshot slots.
    Each snapshot is a self-describing byte image — an 8-byte header
    length, a JSON header (step, leaf paths/dtypes/shapes/offsets,
    user meta), then the raw leaf bytes.

    Write protocol: pick the slot NOT holding the newest committed
    snapshot, reset its doorbell (mark STALE), stream the image into
    the slot through the pool fault shim with bounded
    retry-with-backoff (``core.pool.with_retries``), then ring the
    doorbell — the commit point.  A rank dying mid-write leaves the
    other slot's committed snapshot intact, so ``restore`` always sees
    a consistent image; double buffering is what makes the pool store
    crash-safe without a rename primitive.

    Each ``snapshot`` returns a report priced by the pool cost model
    (per-leaf copy overhead + bytes over the pool server bandwidth +
    the doorbell commit), so planners can budget checkpoint cadence
    against step time.
    """

    capacity_bytes: int = 64 * 1024 * 1024
    slots: int = 2
    cfg: CXLPoolConfig = dataclasses.field(default_factory=CXLPoolConfig)
    retries: int = 3
    backoff_s: float = 0.0
    sleep: Callable[[float], None] = lambda _s: None

    def __post_init__(self) -> None:
        if self.slots < 2:
            raise ValueError("need >= 2 slots for crash-safe commits")
        self.doorbells = DoorbellRegion(self.slots)
        usable = self.capacity_bytes - self.doorbells.region_bytes
        self.slot_bytes = usable // self.slots
        if self.slot_bytes <= 0:
            raise ValueError("pool checkpoint capacity too small")
        self._pool = np.zeros(self.capacity_bytes, dtype=np.uint8)
        self._slot_step: list[int] = [-1] * self.slots  # committed steps
        self.retried = 0  # transient pool faults absorbed by retries

    # -- addressing -------------------------------------------------------
    def slot_offset(self, slot: int) -> int:
        return self.doorbells.region_bytes + slot * self.slot_bytes

    def _next_slot(self) -> int:
        """The slot to overwrite: the one NOT holding the newest
        committed snapshot (round-robin over stale slots)."""
        newest = max(range(self.slots), key=lambda s: self._slot_step[s])
        return (newest + 1) % self.slots

    # -- pool access through the fault shim -------------------------------
    def _store(self, rank: int, offset: int, raw: bytes) -> None:
        def attempt() -> None:
            pool_mod.check_fault("ckpt_write", rank=rank, offset=offset,
                                 size=len(raw))
            self._pool[offset:offset + len(raw)] = np.frombuffer(
                raw, dtype=np.uint8)

        def note(_attempt: int, _exc: Exception) -> None:
            self.retried += 1

        pool_mod.with_retries(attempt, retries=self.retries,
                              backoff_s=self.backoff_s, sleep=self.sleep,
                              on_retry=note)

    def _load(self, rank: int, offset: int, nbytes: int) -> bytes:
        def attempt() -> bytes:
            pool_mod.check_fault("ckpt_read", rank=rank, offset=offset,
                                 size=nbytes)
            return bytes(self._pool[offset:offset + nbytes])

        return pool_mod.with_retries(attempt, retries=self.retries,
                                     backoff_s=self.backoff_s,
                                     sleep=self.sleep)

    # -- cost model -------------------------------------------------------
    def predict_write_s(self, total_bytes: int, n_leaves: int) -> float:
        """Pool cost model for one snapshot: per-leaf memcpy setup, the
        image over the pool server link, one doorbell commit."""
        c = self.cfg
        return (n_leaves * c.memcpy_overhead
                + total_bytes / c.server_bw
                + c.doorbell_latency)

    # -- public API -------------------------------------------------------
    def snapshot(self, step: int, tree: Any, meta: Optional[dict] = None,
                 rank: int = 0) -> dict:
        """Write a snapshot of ``tree`` into the stale slot and commit.

        Raises ``PoolAccessError`` only if a fault persists past the
        retry budget; the previous committed snapshot stays readable
        either way."""
        header, payload = _serialize_tree(step, tree, meta)
        image = (len(header).to_bytes(8, "little") + header + payload)
        if len(image) > self.slot_bytes:
            raise ValueError(
                f"snapshot needs {len(image)} bytes > slot capacity "
                f"{self.slot_bytes}; raise capacity_bytes")
        slot = self._next_slot()
        before = self.retried
        self.doorbells.reset(slot)          # in-flight: not restorable
        self._store(rank, self.slot_offset(slot), image)
        self.doorbells.ring(slot)           # the commit point
        self._slot_step[slot] = step
        n_leaves = len(json.loads(header)["leaves"])
        return {"slot": slot, "step": step, "bytes": len(image),
                "leaves": n_leaves, "retries": self.retried - before,
                "predicted_write_s": self.predict_write_s(
                    len(image), n_leaves)}

    def latest(self) -> Optional[int]:
        """Newest committed (doorbell READY) snapshot step, or None."""
        steps = [self._slot_step[s] for s in range(self.slots)
                 if self.doorbells.is_ready(s) and self._slot_step[s] >= 0]
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                rank: int = 0) -> tuple[Any, dict]:
        """Restore the snapshot for ``step`` (default: newest committed)
        into the structure of ``like``; returns ``(tree, meta)``."""
        if step is None:
            step = self.latest()
            if step is None:
                raise LookupError("no committed pool snapshot")
        cands = [s for s in range(self.slots)
                 if self._slot_step[s] == step and self.doorbells.is_ready(s)]
        if not cands:
            raise LookupError(f"no committed pool snapshot for step {step}")
        base = self.slot_offset(cands[0])
        hlen = int.from_bytes(self._load(rank, base, 8), "little")
        doc = json.loads(self._load(rank, base + 8, hlen))
        payload_base = base + 8 + hlen
        flat, _ = tree_flatten_with_path(like)
        if len(flat) != len(doc["leaves"]):
            raise ValueError(
                f"pool snapshot has {len(doc['leaves'])} leaves, target "
                f"structure has {len(flat)}")
        leaves = []
        for i, (path, leaf) in enumerate(flat):
            ent = doc["leaves"][i]
            if _path_str(path) != ent["path"]:
                raise ValueError(
                    f"leaf {i} path mismatch: snapshot {ent['path']!r} vs "
                    f"target {_path_str(path)!r}")
            raw = self._load(rank, payload_base + ent["offset"],
                             ent["nbytes"])
            arr = np.frombuffer(raw, dtype=np.dtype(ent["dtype"]))
            leaves.append(arr.reshape(ent["shape"]).copy())
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        return tree, doc["meta"]
