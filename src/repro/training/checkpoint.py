"""Checkpointing: pytree save/restore without external deps.

Layout: ``<dir>/step_<n>/arrays.npz`` (flattened leaves, keyed by index)
plus ``tree.json`` (the treedef paths + leaf dtypes/shapes) and
``meta.json``.  Restore rebuilds the exact pytree and validates shapes.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np
from jax.tree_util import DictKey, SequenceKey, tree_flatten_with_path


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, DictKey):
            parts.append(str(k.key))
        elif isinstance(k, SequenceKey):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree: Any,
         meta: Optional[dict] = None) -> str:
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(out, exist_ok=True)
    flat, treedef = tree_flatten_with_path(tree)
    arrays = {}
    index = []
    for i, (path, leaf) in enumerate(flat):
        arrays[f"a{i}"] = np.asarray(leaf)
        index.append(_path_str(path))
    np.savez(os.path.join(out, "arrays.npz"), **arrays)
    with open(os.path.join(out, "tree.json"), "w") as f:
        json.dump({"paths": index}, f)
    with open(os.path.join(out, "meta.json"), "w") as f:
        json.dump({"step": step, **(meta or {})}, f)
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any) -> Any:
    """Restore into the structure of ``like`` (validates paths+shapes)."""
    src = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(src, "tree.json")) as f:
        saved_paths = json.load(f)["paths"]
    data = np.load(os.path.join(src, "arrays.npz"))
    flat, treedef = tree_flatten_with_path(like)
    if len(flat) != len(saved_paths):
        raise ValueError(
            f"checkpoint has {len(saved_paths)} leaves, target structure "
            f"has {len(flat)}")
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        ps = _path_str(path)
        if ps != saved_paths[i]:
            raise ValueError(
                f"leaf {i} path mismatch: checkpoint {saved_paths[i]!r} "
                f"vs target {ps!r}")
        arr = data[f"a{i}"]
        if hasattr(leaf, "shape") and tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{ps}: shape {arr.shape} != {leaf.shape}")
        leaves.append(arr)
    return jax.tree.unflatten(jax.tree.structure(like), leaves)


def load_meta(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "meta.json")) as f:
        return json.load(f)
