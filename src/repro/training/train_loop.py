"""Training step + loop.

The sharded step is a pure ``shard_map`` over the full production mesh
(Megatron-style manual sharding): every collective - FSDP param
AllGather, grad ReduceScatter (via AD transpose), TP AllReduce, MoE
AllToAll, vocab-sharded softmax reductions - goes through the CXL-CCL
``Communicator``, so ``--backend ring|cxl`` swaps the entire
communication layer of the framework.  This is the paper's Sec. 5.5 FSDP
case study generalized to every architecture in the zoo.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import ledger, overlap
from repro.core.api import Communicator
from repro.models import model, sharding
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext, UNSHARDED
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         clip_by_global_norm, linear_warmup_cosine)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    lr: float = 3e-4
    warmup: int = 100
    total_steps: int = 1000
    clip_norm: Optional[float] = 1.0     # unsharded path only
    remat: bool = True
    microbatches: int = 1                # gradient accumulation splits
    backend: str = "ring"                # 'ring' | 'cxl' | 'auto'
    slicing_factor: int = 4
    allreduce_mode: str = "two_phase"
    plan_path: Optional[str] = None      # autotuning plan for 'auto'
    # communication/compute overlap (core.overlap): NCCL-style cap on
    # the fused grad-sync AllReduce buffers; > 0 also switches the FSDP
    # gathers to row-fused buckets, <= 0 restores the per-leaf baseline.
    # prefetch=1 double-buffers the FSDP AllGather (0 restores the
    # serialized gather-then-compute schedule).
    bucket_mb: float = 25.0
    prefetch: int = 1
    # Fused collective+compute kernels (kernels.fused_collectives): the
    # FSDP gathers return the matmul weights as rank-major shard stacks
    # and the consuming matmuls stream them through the fused
    # all_gather+matmul kernel (models.layers.dense).  Requires the
    # bucketed gather path (bucket_mb > 0); the per-leaf reference
    # gather ignores the flag.
    fuse_kernels: bool = False

    @property
    def bucket_bytes(self) -> int:
        return int(self.bucket_mb * 1024 * 1024)


def make_gather_fn(tcfg: TrainConfig, rspecs: dict, pc: ParallelContext,
                   dp_axis):
    """FSDP gather hook for the configured overlap mode: row-fused
    buckets (``core.overlap``; the gather cap is intentionally None -
    a row is one FlatParameter regardless of ``bucket_mb``, which only
    caps the grad-sync buffers) or the per-leaf reference when
    ``bucket_mb <= 0``.  Shared by the trainer and the dry-run so the
    two always lower the same schedule.  ``tcfg.fuse_kernels`` rides
    through to the bucketed path: matmul weights come back as shard
    stacks for the fused all_gather+matmul kernel."""
    if tcfg.bucket_bytes > 0:
        return overlap.make_gather_fn(rspecs, pc, dp_axis,
                                      bucket_bytes=None,
                                      fuse=tcfg.fuse_kernels)
    return sharding.fsdp_gather_fn(rspecs, pc, dp_axis)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    pc: ParallelContext = UNSHARDED,
                    gather_fn=None, param_spec_tree=None,
                    dp_axis=None) -> Callable:
    """Unsharded (or inside-shard_map) train step:
    (params, opt_state, batch) -> (params, opt_state, metrics).

    With ``microbatches > 1`` the local batch is split and gradients are
    accumulated with ``lax.scan`` (bounding activation memory).
    ``param_spec_tree`` enables the replicated-grad AllReduce sync."""
    lr_fn = linear_warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.total_steps)

    def lf(p, b):
        loss, aux = model.loss_fn(p, b, cfg, pc, remat=tcfg.remat,
                                  gather_fn=gather_fn,
                                  prefetch=tcfg.prefetch)
        if pc.dp_axis is not None:
            loss = pc.dp_all_reduce_mean(loss)
        return loss, aux

    def step(params, opt_state: AdamWState, batch):
        mb = tcfg.microbatches
        if mb > 1:
            split = jax.tree.map(
                lambda x: x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                batch)

            def acc_step(acc, b):
                acc_g, acc_loss, acc_aux = acc
                # ledger: AD transposes double every collective's wire
                # bytes (AG<->RS, psum<->psum); remat inside the rows
                # already adds its replay factor in _run_groups' bodies.
                with ledger.scale(2 if not tcfg.remat else 3):
                    (loss, aux), grads = jax.value_and_grad(
                        lf, has_aux=True)(params, b)
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                return (acc_g, acc_loss + loss,
                        jax.tree.map(jnp.add, acc_aux, aux)), None

            zeros_g = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), params)
            zero_aux = {"xent": jnp.float32(0), "aux": jnp.float32(0)}
            with ledger.scale(mb):
                (grads, loss, aux), _ = jax.lax.scan(
                    acc_step, (zeros_g, jnp.float32(0), zero_aux), split)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
            aux = jax.tree.map(lambda a: a / mb, aux)
        else:
            with ledger.scale(2 if not tcfg.remat else 3):
                (loss, aux), grads = jax.value_and_grad(
                    lf, has_aux=True)(params, batch)
        if param_spec_tree is not None:
            if tcfg.bucket_bytes > 0:
                # fused (bucketed) replicated-grad sync: a handful of
                # large AllReduces instead of one per leaf
                grads = overlap.bucketed_sync_grads(
                    grads, param_spec_tree, pc, dp_axis,
                    bucket_bytes=tcfg.bucket_bytes)
            else:
                grads = sharding.sync_grads(grads, param_spec_tree, pc,
                                            dp_axis)
        gnorm = jnp.float32(0.0)
        if tcfg.clip_norm is not None and pc.tp_axis is None:
            grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = lr_fn(opt_state.step)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "lr": lr,
                                   "grad_norm": gnorm, **aux}
    return step


def make_sharded_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh,
                            tp_axis: str = "model",
                            dp_axis=("data",)) -> tuple:
    """Builds the shard_map'ed train step for a production mesh.

    Returns (step_fn, param_specs, batch_specs, pc).  ``step_fn`` takes
    (params, opt_state, batch) with params/opt_state sharded per
    param_specs and the batch sharded over dp.
    """
    from repro.data.pipeline import make_batch_specs

    dp = dp_axis if isinstance(dp_axis, (tuple, list)) else (dp_axis,)
    dp = tuple(a for a in dp if mesh.shape[a] > 1) or (dp[0],)
    dp_spec = dp if len(dp) > 1 else dp[0]
    tp = mesh.shape[tp_axis]

    plan = None
    if tcfg.plan_path is not None:
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import load_plan
        # fingerprint-checked: refuse a plan tuned for other hardware
        plan = load_plan(tcfg.plan_path, pool=CXL_POOL, ib=INFINIBAND)
    comm = Communicator(backend=tcfg.backend,
                        slicing_factor=tcfg.slicing_factor,
                        allreduce_mode=tcfg.allreduce_mode, plan=plan)
    pc = ParallelContext(tp_axis=tp_axis if tp > 1 else None,
                         dp_axis=dp_spec, tp=tp, comm=comm)

    sharding.set_mesh_sizes({a: mesh.shape[a] for a in mesh.axis_names})
    abstract = model.abstract_params(cfg, tp=tp)
    pspecs = sharding.param_specs(abstract, cfg, model_axis=tp_axis,
                                  dp_axis=dp_spec, fsdp=True)
    rspecs = sharding.row_specs(pspecs)
    gather = make_gather_fn(tcfg, rspecs, pc, dp_spec)
    bspecs = make_batch_specs(cfg, dp_spec)
    inner = make_train_step(cfg, tcfg, pc, gather_fn=gather,
                            param_spec_tree=pspecs, dp_axis=dp_spec)

    # optimizer state mirrors the param sharding
    ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    mspecs = {"loss": P(), "lr": P(), "grad_norm": P(), "xent": P(),
              "aux": P()}

    step_fn = jax.jit(jax.shard_map(
        inner, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, mspecs), check_vma=False))
    return step_fn, pspecs, bspecs, pc


def train(cfg: ModelConfig, tcfg: TrainConfig, data_iter, steps: int,
          params=None, key=None, log_every: int = 10,
          log_fn=print) -> tuple:
    """Single-host training loop (CPU smoke / quickstart example)."""
    key = key if key is not None else jax.random.key(0)
    if params is None:
        params = model.init_params(key, cfg, tp=1, dtype=jnp.float32)
    opt_state = adamw_init(params)
    step = jax.jit(make_train_step(cfg, tcfg))
    t0 = time.time()
    metrics = {}
    for i, batch in zip(range(steps), data_iter):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % log_every == 0 or i == steps - 1:
            log_fn(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                   f"lr {float(metrics['lr']):.2e} "
                   f"({(time.time()-t0):.1f}s)")
    return params, opt_state, metrics
