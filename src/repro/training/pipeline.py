"""Pipeline parallelism: schedules, bubble accounting, SPMD execution.

**Schedules.**  ``schedule_1f1b`` / ``schedule_interleaved`` produce
per-stage op lists (the order a multi-controller runtime would execute)
and ``simulate`` runs them under greedy unit-time execution with the
true dataflow dependencies - F(vs, m) after F(vs-1, m), B(vs, m) after
B(vs+1, m) and F(vs, m) - raising ``PipelineDeadlock`` if the lists
ever wedge.  Bubble closed forms (per stage, in per-op time units; an
interleaved chunk op is ``1/v`` of a 1F1B stage op):

* 1F1B:         span = 2M + 2(S-1),   bubble = 2(S-1)
* interleaved:  span = 2Mv + 2(S-1),  bubble = 2(S-1)  (= 2(S-1)/v
  stage-op units - the Megatron-style 1/v bubble shrink; requires
  M % S == 0 so chunk groups tile the pipeline)

**Execution.**  The single-controller SPMD step expresses the pipeline
as a scan over ``M + S - 1`` ticks: at tick ``t`` stage ``s`` works on
microbatch ``m = t - s`` (masked outside ``[0, M)``), pushing
activations one hop with ``Communicator.send`` - so its AD transpose
replays the reverse pipeline (``ppermute`` transposes to the inverse
permute) and the backward handoff rides the same tuned p2p cell.  The
schedule choice steers the host-side bubble/cost accounting and the op
ordering a real runtime would follow; the SPMD arithmetic is
schedule-independent (association order aside), with wire bytes and
op totals matching the schedule's F/B counts.  This mirrors how the
doorbell protocol is modelled-not-lowered on the TPU mesh
(``core.mesh_collectives``): SSA data dependence stands in for the
runtime's explicit synchronization.

Equivalence: the pipelined loss equals the single-pass ``model.loss_fn``
loss on the same batch up to fp association order - each microbatch
crosses each layer exactly once and the per-microbatch means average
back to the full-batch mean (``_mesh_runner.check_pipeline_train``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import ledger
from repro.core.api import Communicator
from repro.models import layers, model, pipeline_stages
from repro.models.config import ModelConfig
from repro.models.pcontext import ParallelContext
from repro.optim import AdamWState, adamw_update, linear_warmup_cosine

SCHEDULES = ("1f1b", "interleaved")


# --------------------------------------------------------------------- #
# schedules (host side: op ordering + bubble accounting)
# --------------------------------------------------------------------- #

@dataclasses.dataclass(frozen=True)
class Op:
    """One scheduled unit of stage work: a forward or backward pass of
    one microbatch through one model chunk (chunk 0 unless
    interleaved)."""
    kind: str             # 'F' | 'B'
    microbatch: int
    chunk: int = 0


class PipelineDeadlock(RuntimeError):
    """Greedy execution of the per-stage op lists wedged: some stage's
    next op waits on work that can never complete."""


def schedule_1f1b(n_stages: int, n_microbatches: int) -> list[list[Op]]:
    """PipeDream-flush 1F1B: stage ``s`` runs ``min(S-1-s, M)`` warmup
    forwards, then alternates F/B in steady state, then drains the
    remaining backwards.  Peak live activations per stage are bounded
    by the warmup depth (S - s) instead of M (GPipe)."""
    S, M = n_stages, n_microbatches
    if S < 1 or M < 1:
        raise ValueError("need n_stages >= 1 and n_microbatches >= 1")
    out = []
    for s in range(S):
        warm = min(S - 1 - s, M)
        ops = [Op("F", m) for m in range(warm)]
        for i in range(M - warm):
            ops.append(Op("F", warm + i))
            ops.append(Op("B", i))
        for i in range(max(M - warm, 0), M):
            ops.append(Op("B", i))
        out.append(ops)
    return out


def schedule_interleaved(n_stages: int, n_microbatches: int,
                         n_chunks: int = 2) -> list[list[Op]]:
    """Interleaved 1F1B (Megatron-style looping pipeline): each
    physical stage hosts ``v = n_chunks`` model chunks, so virtual
    stage ``c*S + s`` lives on physical stage ``s`` and a microbatch
    loops through the pipeline ``v`` times.  Forward order walks chunk
    groups of ``S`` microbatches (chunk 0 of microbatches 0..S-1, then
    chunk 1 of the same group, ...), which is why ``M % S == 0`` is
    required; backward mirrors it with chunks reversed.  Bubble per
    stage is 2(S-1) *chunk* ops = 2(S-1)/v stage ops."""
    S, M, v = n_stages, n_microbatches, n_chunks
    if S < 1 or M < 1:
        raise ValueError("need n_stages >= 1 and n_microbatches >= 1")
    if v < 1:
        raise ValueError("n_chunks must be >= 1")
    if v == 1:
        return schedule_1f1b(S, M)
    if M % S:
        raise ValueError(
            f"interleaved schedule needs microbatches % stages == 0 "
            f"(got M={M}, S={S})")
    total = M * v

    def f_op(k: int) -> Op:
        g = k % (S * v)
        return Op("F", (k // (S * v)) * S + g % S, g // S)

    def b_op(k: int) -> Op:
        g = k % (S * v)
        return Op("B", (k // (S * v)) * S + g % S, v - 1 - g // S)

    out = []
    for s in range(S):
        warm = min((S - 1 - s) * 2 + (v - 1) * S, total)
        ops = [f_op(k) for k in range(warm)]
        for i in range(total - warm):
            ops.append(f_op(warm + i))
            ops.append(b_op(i))
        for i in range(max(total - warm, 0), total):
            ops.append(b_op(i))
        out.append(ops)
    return out


def make_schedule(schedule: str, n_stages: int, n_microbatches: int,
                  n_chunks: int = 2) -> list[list[Op]]:
    if schedule == "1f1b":
        return schedule_1f1b(n_stages, n_microbatches)
    if schedule == "interleaved":
        return schedule_interleaved(n_stages, n_microbatches, n_chunks)
    raise ValueError(f"unknown schedule {schedule!r}; one of {SCHEDULES}")


def simulate(per_stage_ops: list[list[Op]], n_chunks: int = 1) -> int:
    """Greedy unit-time execution of the per-stage op lists under the
    pipeline dataflow dependencies.  Each stage runs its ops strictly
    in list order, one per tick, starting an op only when its inputs
    exist: F(vs, m) needs F(vs-1, m); B(vs, m) needs F(vs, m) and
    B(vs+1, m) (virtual stage vs = chunk*S + s).  Returns the span in
    ticks; raises :class:`PipelineDeadlock` if no stage can make
    progress before all ops complete."""
    S = len(per_stage_ops)
    V = n_chunks * S
    done: dict = {}
    idx = [0] * S
    total = sum(len(o) for o in per_stage_ops)
    ndone, t = 0, 0
    while ndone < total:
        runnable = []
        for s in range(S):
            if idx[s] >= len(per_stage_ops[s]):
                continue
            op = per_stage_ops[s][idx[s]]
            vs = op.chunk * S + s
            if op.kind == "F":
                ok = vs == 0 or done.get(("F", vs - 1, op.microbatch),
                                         total + 1) <= t
            else:
                ok = done.get(("F", vs, op.microbatch), total + 1) <= t \
                    and (vs == V - 1
                         or done.get(("B", vs + 1, op.microbatch),
                                     total + 1) <= t)
            if ok:
                runnable.append((s, op, vs))
        if not runnable:
            stuck = {s: per_stage_ops[s][idx[s]] for s in range(S)
                     if idx[s] < len(per_stage_ops[s])}
            raise PipelineDeadlock(
                f"wedged at tick {t} with {total - ndone} ops left: "
                f"{stuck}")
        for s, op, vs in runnable:
            done[(op.kind, vs, op.microbatch)] = t + 1
            idx[s] += 1
            ndone += 1
        t += 1
    return t


def bubble_count(n_stages: int, n_microbatches: int,
                 schedule: str = "1f1b", n_chunks: int = 2) -> int:
    """Per-stage idle ticks (in per-op time units: chunk ops for the
    interleaved schedule): ``2 * (n_stages - 1)`` for both schedules -
    the interleaved win is that its op unit is ``1/v`` of a stage op,
    so the same tick count is ``2(S-1)/v`` stage-op units of idle
    time."""
    if schedule not in SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}")
    if n_stages < 1 or n_microbatches < 1:
        raise ValueError("need n_stages >= 1 and n_microbatches >= 1")
    return 2 * (n_stages - 1)


def bubble_fraction(n_stages: int, n_microbatches: int,
                    schedule: str = "1f1b", n_chunks: int = 2) -> float:
    """Idle fraction of the pipelined step: bubble / span.  For 1F1B
    this is ``(S-1)/(M + S - 1)``; interleaving divides the bubble's
    stage-op units by ``v``."""
    v = n_chunks if schedule == "interleaved" else 1
    busy = 2 * n_microbatches * v
    bub = bubble_count(n_stages, n_microbatches, schedule, n_chunks)
    return bub / (busy + bub)


# --------------------------------------------------------------------- #
# SPMD execution
# --------------------------------------------------------------------- #

def pipeline_loss_fn(params, batch: dict, cfg: ModelConfig,
                     pc: ParallelContext, *, stage_axis: str,
                     n_microbatches: int, remat: bool = True):
    """Pipelined forward over the ``stage_axis`` mesh axis; call under
    ``jax.grad`` for the reverse pipeline.  ``params`` is the standard
    ``model.init_params`` pytree with the stacked layer leaves sharded
    over the stage axis (``pipeline_stages.stage_param_specs``); the
    local batch is split into ``n_microbatches`` along the batch dim.
    Returns (loss, aux) matching ``model.loss_fn`` semantics (loss is
    replicated across stages via one scalar all_reduce)."""
    S = lax.axis_size(stage_axis)
    sidx = lax.axis_index(stage_axis)
    M = n_microbatches
    tokens, labels = batch["tokens"], batch["labels"]
    if tokens.shape[0] % M:
        raise ValueError(f"local batch {tokens.shape[0]} not divisible "
                         f"by {M} microbatches")
    mb = tokens.shape[0] // M
    seq = tokens.shape[1]
    tok_mb = tokens.reshape((M, mb, seq))
    lab_mb = labels.reshape((M, mb, seq))
    mask = batch.get("loss_mask")
    mask_mb = mask.reshape((M, mb, seq)) if mask is not None else None
    positions = jnp.arange(seq)
    is_first = sidx == 0
    is_last = sidx == S - 1

    def tick(carry, t):
        h_recv, loss, aux = carry
        m = t - sidx                     # this stage's microbatch now
        valid = (m >= 0) & (m < M)
        mi = jnp.clip(m, 0, M - 1)
        tok = lax.dynamic_index_in_dim(tok_mb, mi, 0, keepdims=False)
        emb = layers.embed_tokens(params["embed"], tok, cfg, pc)
        h_in = jnp.where(is_first, emb.astype(h_recv.dtype), h_recv)
        h_out, aux_t = pipeline_stages.stage_forward(
            params["g0"], h_in, cfg, pc, positions, remat=remat)
        # mask the warmup/drain ticks so they contribute nothing in
        # either direction (the sender of an invalid tick sent zeros,
        # so every rank's h_in is always finite)
        h_out = jnp.where(valid, h_out, jnp.zeros_like(h_out))
        hn = layers.rms_norm(h_out, params["final_norm"], cfg.norm_eps)
        logits = layers.lm_logits(params["embed"], hn, cfg, pc)
        lab = lax.dynamic_index_in_dim(lab_mb, mi, 0, keepdims=False)
        mk = None if mask_mb is None else lax.dynamic_index_in_dim(
            mask_mb, mi, 0, keepdims=False)
        xent = layers.sharded_xent(logits, lab, pc, mask=mk,
                                   vocab_size=cfg.vocab_size)
        loss = loss + jnp.where(is_last & valid, xent, 0.0)
        aux = aux + jnp.where(valid, aux_t, 0.0)
        h_next = pc.comm.send(h_out, stage_axis)
        return (h_next, loss, aux), None

    h0 = jnp.zeros((mb, seq, cfg.d_model),
                   jax.tree.leaves(params["embed"])[0].dtype)
    ticks = M + S - 1
    with ledger.scale(ticks):
        (_, loss, aux), _ = lax.scan(
            tick, (h0, jnp.float32(0.0), jnp.float32(0.0)),
            jnp.arange(ticks))
    # per-microbatch means average back to the full-batch mean; the
    # loss lives on the last stage (zeros elsewhere), aux on each
    # owning stage - one scalar sum over the stage axis shares both.
    # The shared value rides under stop_gradient: under shard_map every
    # stage rank seeds its own cotangent, so differentiating the psum
    # itself would scale every grad by the stage count - each rank must
    # differentiate only its local contribution (whose cotangents still
    # reach the other stages' slabs through the transposed p2p hops).
    def share(x):
        s = pc.comm.all_reduce(x, stage_axis)
        return (x + lax.stop_gradient(s - x)) / M

    loss, aux = share(loss), share(aux)
    return loss + aux, {"xent": loss, "aux": aux}


def sync_stage_grads(grads, pc: ParallelContext, stage_axis: str):
    """Sum the stage-replicated leaves' grads over the stage axis: the
    embedding is consumed at both pipeline ends (tied weights), the
    final norm only by the last stage - each rank holds a partial
    gradient, and AdamW needs them identical.  Layer-stacked leaves
    (``g*``) are stage-local slabs and stay untouched."""
    return {k: (v if k.startswith("g") else jax.tree.map(
        lambda g: pc.comm.all_reduce(g, stage_axis), v))
            for k, v in grads.items()}


def make_pipeline_train_step(cfg: ModelConfig, tcfg,
                             pc: ParallelContext, *, stage_axis: str,
                             n_stages: int, n_microbatches: int,
                             schedule: str = "1f1b",
                             n_chunks: int = 2):
    """Pipeline-parallel train step for use inside ``shard_map``:
    (params, opt_state, batch) -> (params, opt_state, metrics), the PP
    analog of ``train_loop.make_train_step``.  Data parallelism over
    ``pc.dp_axis`` is plain replicated-grad DP (grads psum-averaged
    over the data axis after the stage-axis sync).  ``schedule`` /
    ``n_chunks`` drive the bubble accounting reported in the metrics
    (and validate the schedule is realizable for these shapes); the
    SPMD arithmetic is schedule-independent (module docstring)."""
    make_schedule(schedule, n_stages, n_microbatches, n_chunks)
    lr_fn = linear_warmup_cosine(tcfg.lr, tcfg.warmup, tcfg.total_steps)
    bub = bubble_fraction(n_stages, n_microbatches, schedule, n_chunks)

    def lf(p, b):
        loss, aux = pipeline_loss_fn(
            p, b, cfg, pc, stage_axis=stage_axis,
            n_microbatches=n_microbatches, remat=tcfg.remat)
        if pc.dp_axis is not None:
            loss = pc.dp_all_reduce_mean(loss)
        return loss, aux

    def step(params, opt_state, batch):
        # ledger: AD transposes double every collective's wire bytes;
        # remat replays the forward once more (same convention as
        # train_loop.make_train_step)
        with ledger.scale(2 if not tcfg.remat else 3):
            (loss, aux), grads = jax.value_and_grad(
                lf, has_aux=True)(params, batch)
        grads = sync_stage_grads(grads, pc, stage_axis)
        if pc.dp_axis is not None:
            grads = jax.tree.map(
                lambda g: pc.dp_all_reduce_mean(g), grads)
        lr = lr_fn(opt_state.step)
        params, opt_state = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, {"loss": loss, "lr": lr,
                                   "bubble_fraction": jnp.float32(bub),
                                   **aux}
    return step


def make_sharded_pipeline_step(cfg: ModelConfig, tcfg, mesh, *,
                               n_microbatches: int,
                               stage_axis: str = "stage",
                               dp_axis: Optional[str] = "data",
                               schedule: str = "1f1b",
                               n_chunks: int = 2) -> tuple:
    """Builds the shard_map'ed pipeline train step for a
    (stage, data) production mesh - the PP analog of
    ``train_loop.make_sharded_train_step``.

    Returns (step_fn, param_specs, batch_specs, pc).  Layer-stacked
    params are sharded over the stage axis (each rank holds its slab of
    rows), embedding/final-norm replicated; the batch is sharded over
    the data axis and replicated across stages (every stage indexes its
    own microbatch per tick).
    """
    from repro.data.pipeline import make_batch_specs

    n_stages = int(mesh.shape[stage_axis])
    pipeline_stages.uniform_stage_rows(cfg, n_stages)
    dp = dp_axis if dp_axis and mesh.shape.get(dp_axis, 1) > 1 else None

    plan = None
    if tcfg.plan_path is not None:
        from repro.core.hw import CXL_POOL, INFINIBAND
        from repro.tuner import load_plan
        plan = load_plan(tcfg.plan_path, pool=CXL_POOL, ib=INFINIBAND)
    comm = Communicator(backend=tcfg.backend,
                        slicing_factor=tcfg.slicing_factor,
                        allreduce_mode=tcfg.allreduce_mode, plan=plan)
    pc = ParallelContext(tp_axis=None, dp_axis=dp, tp=1, comm=comm)

    abstract = model.abstract_params(cfg, tp=1)
    pspecs = pipeline_stages.stage_param_specs(abstract, stage_axis)
    bspecs = make_batch_specs(cfg, dp)   # dp=None -> replicated
    inner = make_pipeline_train_step(
        cfg, tcfg, pc, stage_axis=stage_axis, n_stages=n_stages,
        n_microbatches=n_microbatches, schedule=schedule,
        n_chunks=n_chunks)

    ospecs = AdamWState(step=P(), mu=pspecs, nu=pspecs)
    mspecs = {"loss": P(), "lr": P(), "bubble_fraction": P(),
              "xent": P(), "aux": P()}
    step_fn = jax.jit(jax.shard_map(
        inner, mesh=mesh, in_specs=(pspecs, ospecs, bspecs),
        out_specs=(pspecs, ospecs, mspecs), check_vma=False))
    return step_fn, pspecs, bspecs, pc
