from repro.training.train_loop import (TrainConfig, make_train_step,
                                       make_sharded_train_step, train)

__all__ = ["TrainConfig", "make_train_step", "make_sharded_train_step",
           "train"]
